"""The structural HLO analyzer must agree with hand-computed FLOPs and
collective bytes — including inside scanned loops, where XLA:CPU's own
cost_analysis undercounts (while bodies counted once)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import HloAnalysis, _shape_info


def _analyze(fn, *args):
    comp = jax.jit(fn).lower(*args).compile()
    return HloAnalysis(comp.as_text())


def test_plain_matmul_flops():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    h = _analyze(lambda x, y: x @ y, a, b)
    assert h.dot_flops == 2 * 128 * 256 * 64


def test_scanned_matmul_flops_scales_with_trip_count():
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)

    def make(n):
        def f(w, x):
            def body(c, _):
                return jnp.dot(c, w), ()
            y, _ = jax.lax.scan(body, x, None, length=n)
            return y
        return f

    h3 = _analyze(make(3), w, x)
    h9 = _analyze(make(9), w, x)
    per_iter = 2 * 64 * 128 * 128
    assert h3.dot_flops == 3 * per_iter
    assert h9.dot_flops == 9 * per_iter


def test_scanned_equals_unrolled():
    """The whole point: scanned and unrolled programs report the same flops."""
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)

    def scanned(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), ()
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y

    def unrolled(w, x):
        for _ in range(5):
            x = jnp.tanh(x @ w)
        return x

    hs, hu = _analyze(scanned, w, x), _analyze(unrolled, w, x)
    assert hs.dot_flops == hu.dot_flops == 5 * 2 * 32 * 64 * 64


def test_nested_scan_multipliers():
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)

    def f(w, x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, ()
            c, _ = jax.lax.scan(inner, c, None, length=4)
            return c, ()
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    h = _analyze(f, w, x)
    assert h.dot_flops == 3 * 4 * 2 * 8 * 32 * 32


def test_batched_dot_contracting_dims():
    a = jax.ShapeDtypeStruct((4, 16, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 8), jnp.float32)
    h = _analyze(lambda x, y: jnp.einsum("bij,bjk->bik", x, y), a, b)
    assert h.dot_flops == 2 * 4 * 16 * 32 * 8


def test_shape_info_tuple_and_comments():
    n, b, dims = _shape_info("(s32[], bf16[1,256]{1,0}, /*index=5*/f32[4,8]{1,0})")
    assert n == 1 + 256 + 32
    assert b == 4 + 512 + 128


HLO_FIXTURE = """\
HloModule fixture, is_scheduled=true

ENTRY %main_spmd (p0: f32[64,128]) -> f32[64,128] {
  %p0 = f32[64,128]{1,0} parameter(0)
  %ag = f32[64,256]{1,0} all-gather(%p0), channel_id=1, replica_groups=[2,2]<=[4], dimensions={1}
  %sl = f32[64,128]{1,0} slice(%ag), slice={[0:64],[0:128]}
  %ar = f32[64,128]{1,0} all-reduce(%sl), channel_id=2, replica_groups=[2,2]<=[4], to_apply=%add
  %rs = f32[32,128]{1,0} reduce-scatter(%ar), channel_id=3, replica_groups=[2,2]<=[4], dimensions={0}
  ROOT %cp = f32[64,128]{1,0} collective-permute(%ar), channel_id=4, source_target_pairs={{0,1},{1,0}}
}
"""


def test_collective_bytes_semantics():
    h = HloAnalysis(HLO_FIXTURE)
    s = h.summary()
    # all-gather at gathered size; all-reduce/reduce-scatter/permute at operand
    assert s["collective_bytes"]["all-gather"] == 64 * 256 * 4
    assert s["collective_bytes"]["all-reduce"] == 64 * 128 * 4
    assert s["collective_bytes"]["reduce-scatter"] == 64 * 128 * 4
    assert s["collective_bytes"]["collective-permute"] == 64 * 128 * 4


def test_real_collectives_on_sharded_program():
    """End-to-end: psum over 1-device mesh emits no cross-device traffic, but
    the analyzer still parses the module without error."""
    mesh = jax.make_mesh((1,), ("d",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32,
                             sharding=NamedSharding(mesh, P("d")))
    h = _analyze(lambda a: (a @ a.T).sum(), x)
    assert h.flops > 0


def test_dus_charged_at_region_size():
    buf = jax.ShapeDtypeStruct((1024, 128), jnp.float32)
    upd = jax.ShapeDtypeStruct((1, 128), jnp.float32)

    def f(b, u):
        def body(c, i):
            return jax.lax.dynamic_update_slice(c, u, (i, 0)), ()
        y, _ = jax.lax.scan(body, b, jnp.arange(8))
        return y

    h = _analyze(f, buf, upd)
    # 8 updates of one row — must NOT charge 8 full-buffer copies
    assert h.bytes_accessed < 1024 * 128 * 4 * 4
