"""Island-model parallel evolution engine: determinism, migration, shared
scorer cache / refuted memory, batched scoring, persistence + resume."""
import json
import os

import pytest

from repro.core import (BatchScorer, ContinuousEvolution, IslandEvolution,
                        IslandSpec, KernelGenome, RefutedMemory, Scorer,
                        Toolbelt, seed_genome)
from repro.core.islands import EpochMemoryView, Island
from repro.core.knowledge import KnowledgeBase
from repro.core.perfmodel import BenchConfig, suite_by_name
from repro.core.population import Lineage

FAST_SUITE = [BenchConfig("c4k", 8, 16, 16, 4096, causal=True),
              BenchConfig("n4k", 8, 16, 16, 4096, causal=False)]


def _lineage_fingerprint(lineage):
    return [(c.genome.key(), round(c.geomean, 9), c.note) for c in lineage.commits]


def _run_engine(**kw):
    max_steps = kw.pop("max_steps", 6)
    defaults = dict(n_islands=3, suite=FAST_SUITE, migration_interval=2, seed=11)
    defaults.update(kw)
    eng = IslandEvolution(**defaults)
    try:
        rep = eng.run(max_steps=max_steps)
    finally:
        eng.close()
    return eng, rep


# -- BatchScorer ----------------------------------------------------------------


def test_batch_scorer_matches_serial_scorer():
    plain = Scorer(suite=FAST_SUITE)
    batch = BatchScorer(Scorer(suite=FAST_SUITE))
    genomes = [seed_genome(), seed_genome().with_(block_q=256),
               seed_genome().with_(kv_in_grid=True)]
    for g in genomes:
        assert batch(g).values == plain(g).values
    batch.close()


def test_batch_scorer_map_preserves_order_and_dedupes():
    batch = BatchScorer(Scorer(suite=FAST_SUITE, check_correctness=False))
    g1, g2 = seed_genome(), seed_genome().with_(block_q=256)
    svs = batch.map([g1, g2, g1, g2, g1])
    assert [sv.values for sv in svs] == \
        [batch(g1).values, batch(g2).values, batch(g1).values,
         batch(g2).values, batch(g1).values]
    # 5 requests, 2 distinct genomes -> 2 paid evaluations
    assert batch.n_evaluations == 2
    batch.close()


def test_batch_scorer_concurrent_same_genome_single_eval():
    import concurrent.futures as cf
    batch = BatchScorer(Scorer(suite=FAST_SUITE, check_correctness=False))
    g = seed_genome().with_(block_q=512)
    # hammer the same genome from many threads WITHOUT map()'s dedup: the
    # in-flight protocol must still collapse everything onto one evaluation
    with cf.ThreadPoolExecutor(8) as ex:
        svs = list(ex.map(batch, [g] * 16))
    assert len({sv.values for sv in svs}) == 1
    assert batch.n_evaluations == 1
    assert batch.cache_hits == 15
    batch.close()


# -- shared refuted memory -------------------------------------------------------


def test_refuted_memory_shared_across_toolbelts():
    mem = RefutedMemory()
    sc = Scorer(suite=FAST_SUITE, check_correctness=False)
    t1 = Toolbelt(sc, KnowledgeBase(), Lineage(), memory=mem)
    t2 = Toolbelt(sc, KnowledgeBase(), Lineage(), memory=mem)
    g, edit = seed_genome(), {"block_q": 256}
    t1.remember_refuted(g, edit, "regressed")
    assert t2.is_refuted(g, edit)
    assert t2.stats()["refuted_memories"] == 1


def test_epoch_memory_view_isolates_until_publish():
    shared = RefutedMemory()
    a, b = EpochMemoryView(shared), EpochMemoryView(shared)
    a.add(("k", ("e",)), "note")
    assert ("k", ("e",)) in a
    assert ("k", ("e",)) not in b          # not visible mid-epoch
    a.publish()
    assert ("k", ("e",)) not in b          # b still frozen pre-publish
    b.publish()                            # barrier refreshes b's snapshot
    assert ("k", ("e",)) in b
    assert len(shared) == 1


# -- engine: determinism ---------------------------------------------------------


def test_islands_deterministic_under_fixed_seed():
    eng1, _ = _run_engine()
    eng2, _ = _run_engine()
    for a, b in zip(eng1.islands, eng2.islands):
        assert _lineage_fingerprint(a.lineage) == _lineage_fingerprint(b.lineage)


def test_islands_different_seeds_diverge_inits():
    # diverse initialization is seed-dependent for the default specs
    from repro.core.islands import default_specs
    inits1 = [s.init_genome for s in default_specs(4, seed=0)]
    inits2 = [s.init_genome for s in default_specs(4, seed=1)]
    assert inits1[0] is None and inits2[0] is None     # island0 is always x0
    assert inits1 != inits2


# -- engine: migration -----------------------------------------------------------


def test_migration_preserves_global_best():
    eng, rep = _run_engine()
    # the aggregate best equals the max over island bests: migration copies
    # commits, never removes them
    assert rep.best_geomean == pytest.approx(
        max(isl.best_geomean() for isl in eng.islands))
    assert rep.best_geomean > 0


def test_migrant_adopted_only_on_strict_improvement():
    sc = BatchScorer(Scorer(suite=FAST_SUITE, check_correctness=False))
    strong = Island("strong", sc)
    weak = Island("weak", sc)
    g_good = KernelGenome(block_q=512, block_k=1024, rescale_mode="branchless",
                          mask_mode="block_skip", div_mode="deferred",
                          kv_in_grid=True)
    sv = sc(g_good)
    strong.lineage.update(g_good, sv, "hand-planted best")
    weak.lineage.update(seed_genome(), sc(seed_genome()), "seed")
    assert weak.accept_migrant(strong.lineage.best(), "strong")
    assert weak.best_geomean() == pytest.approx(strong.best_geomean())
    # re-offering the same commit is no longer a strict improvement
    assert not weak.accept_migrant(strong.lineage.best(), "strong")
    # and the strong island never adopts the weak seed
    assert not strong.accept_migrant(weak.lineage.commits[0], "weak")
    sc.close()


def test_cross_suite_migration_rescoring():
    """A migrant is re-scored on the recipient's suite: values must come from
    the recipient suite, not the donor's."""
    sc_mha = BatchScorer(Scorer(suite=suite_by_name("mha"),
                                check_correctness=False))
    sc_dec = BatchScorer(Scorer(suite=suite_by_name("decode"),
                                check_correctness=False))
    donor = Island("mha", sc_mha)
    recipient = Island("decode", sc_dec)
    g = KernelGenome(block_q=256, block_k=512, rescale_mode="branchless",
                     mask_mode="block_skip", kv_in_grid=True)
    donor.lineage.update(g, sc_mha(g), "evolved on mha")
    assert recipient.accept_migrant(donor.lineage.best(), "mha")
    b = recipient.lineage.best()
    assert len(b.values) == len(sc_dec.suite)
    assert b.values == sc_dec(g).values
    sc_mha.close(); sc_dec.close()


# -- engine: migrant payload policy (best | top-k) --------------------------------


def test_lineage_top_k_distinct_and_deterministic():
    ln = Lineage()
    sc = Scorer(suite=FAST_SUITE, check_correctness=False)
    g1, g2 = seed_genome(), seed_genome().with_(block_q=256)
    ln.update(g1, sc(g1), "first")
    ln.update(g2, sc(g2), "second")
    ln.update(g1, sc(g1), "first again")        # duplicate genome: collapses
    top = ln.top(3)
    assert len(top) == 2                        # distinct genomes only
    assert {c.genome.key() for c in top} == {g1.key(), g2.key()}
    assert top[0].geomean >= top[1].geomean     # geomean-descending payload
    assert ln.top(1) == [ln.best()]
    # equal-geomean duplicates keep the EARLIEST version (stable payload)
    dup = next(c for c in top if c.genome.key() == g1.key())
    assert dup.version == 0


def test_accept_migrants_adopts_best_survivor_on_recipient_suite():
    """The top-k point: the donor's best at home can lose to a runner-up on
    the recipient's suite — the recipient re-scores ALL donated commits and
    adopts the best survivor."""
    sc_mha = BatchScorer(Scorer(suite=suite_by_name("mha"),
                                check_correctness=False))
    sc_dec = BatchScorer(Scorer(suite=suite_by_name("decode"),
                                check_correctness=False))
    donor = Island("mha", sc_mha)
    recipient = Island("decode", sc_dec)
    g_a = KernelGenome(block_q=256, block_k=512, rescale_mode="branchless",
                       mask_mode="block_skip", kv_in_grid=True)
    g_b = seed_genome().with_(block_q=64, block_k=256, kv_in_grid=True)
    for g, note in ((g_a, "donor A"), (g_b, "donor B")):
        donor.lineage.update(g, sc_mha(g), note)
    donated = donor.lineage.top(2)
    # pick whichever donated genome scores best on the recipient's suite and
    # assert accept_migrants lands exactly that one
    by_recipient = max(donated, key=lambda c: sc_dec(c.genome).geomean)
    assert recipient.accept_migrants(donated, "mha")
    b = recipient.lineage.best()
    assert b.genome.key() == by_recipient.genome.key()
    assert b.values == sc_dec(by_recipient.genome).values
    # strict improvement: re-offering the same payload is rejected
    assert not recipient.accept_migrants(donated, "mha")
    sc_mha.close(); sc_dec.close()


def test_migrant_policy_default_and_k1_bit_identical():
    """'best' stays the default and bit-identical to the historical lineages;
    'top-k' with k=1 donates the same single commit, so it must match too."""
    base, _ = _run_engine()
    named, _ = _run_engine(migrant_policy="best")
    k1, _ = _run_engine(migrant_policy="top-k", migrant_k=1)
    for a, b, c in zip(base.islands, named.islands, k1.islands):
        assert _lineage_fingerprint(a.lineage) == _lineage_fingerprint(b.lineage)
        assert _lineage_fingerprint(a.lineage) == _lineage_fingerprint(c.lineage)


def test_migrant_policy_topk_runs_and_is_deterministic():
    a, rep = _run_engine(migrant_policy="top-k", migrant_k=3)
    b, _ = _run_engine(migrant_policy="top-k", migrant_k=3)
    assert rep.commits > 0
    for x, y in zip(a.islands, b.islands):
        assert _lineage_fingerprint(x.lineage) == _lineage_fingerprint(y.lineage)


def test_migrant_policy_validation():
    with pytest.raises(ValueError, match="unknown migrant_policy"):
        IslandEvolution(n_islands=2, suite=FAST_SUITE,
                        migrant_policy="diversity")
    with pytest.raises(ValueError, match="migrant_k"):
        IslandEvolution(n_islands=2, suite=FAST_SUITE,
                        migrant_policy="top-k", migrant_k=0)


# -- engine: shared scorer cache --------------------------------------------------


def test_shared_cache_cheaper_than_independent_runs():
    """N islands sharing one scorer must pay for strictly fewer evaluations
    than N independent serial runs of the same islands."""
    n = 3
    eng, rep = _run_engine(n_islands=n)
    shared_evals = rep.evaluations
    assert rep.cache_hits > 0

    independent = 0
    from repro.core.islands import default_specs
    for spec in default_specs(n, seed=11):
        agent_kwargs = {}
        if spec.init_genome is not None:
            agent_kwargs["seed"] = spec.init_genome
        from repro.core.variation import make_operator
        evo = ContinuousEvolution(
            scorer=Scorer(suite=FAST_SUITE),
            operator=make_operator("avo", agent_kwargs=agent_kwargs))
        evo.run(max_steps=6)
        independent += evo.scorer.n_evaluations
    assert shared_evals < independent


# -- persistence / resume ---------------------------------------------------------


def test_archipelago_save_load_roundtrip(tmp_path):
    p = str(tmp_path / "arch.json")
    eng, _ = _run_engine(persist_path=p)
    assert os.path.exists(p)
    with open(p) as f:
        payload = json.load(f)
    assert payload["format"] == "archipelago.v1"
    assert len(payload["islands"]) == len(eng.islands)

    eng2 = IslandEvolution(n_islands=3, suite=FAST_SUITE,
                           migration_interval=2, seed=11, persist_path=p)
    try:
        eng2.load_state(p)
        for a, b in zip(eng.islands, eng2.islands):
            assert _lineage_fingerprint(a.lineage) == _lineage_fingerprint(b.lineage)
    finally:
        eng2.close()


def test_killed_run_resumes_with_identical_lineages(tmp_path):
    """Persisted state at the barrier IS the whole search state of the
    lineages: resuming from it reproduces them exactly and keeps going."""
    p = str(tmp_path / "arch.json")
    eng, _ = _run_engine(persist_path=p)
    fingerprints = {isl.name: _lineage_fingerprint(isl.lineage)
                    for isl in eng.islands}
    del eng                                       # "kill" the run

    resumed = IslandEvolution.resume(p, n_islands=3, suite=FAST_SUITE,
                                     migration_interval=2, seed=11)
    try:
        for isl in resumed.islands:
            assert _lineage_fingerprint(isl.lineage) == fingerprints[isl.name]
        n_before = {isl.name: len(isl.lineage) for isl in resumed.islands}
        resumed.run(max_steps=2)
        for isl in resumed.islands:
            assert len(isl.lineage) >= n_before[isl.name]
    finally:
        resumed.close()


def test_resume_restores_supervisor_and_refuted_memory(tmp_path):
    """Exact resume needs more than lineages: the stall counters and the
    shared refuted-edit memory are part of the search state too."""
    p = str(tmp_path / "arch.json")
    eng, _ = _run_engine(persist_path=p)
    sup = {i.name: i.supervisor.state() for i in eng.islands}
    mem = eng.memory.to_payload()

    resumed = IslandEvolution.resume(p, n_islands=3, suite=FAST_SUITE,
                                     migration_interval=2, seed=11)
    try:
        assert {i.name: i.supervisor.state() for i in resumed.islands} == sup
        assert resumed.memory.to_payload() == mem
        if mem:   # the epoch views must see restored refutations immediately
            entry = (mem[0][0], tuple(tuple(pair) for pair in mem[0][1]))
            assert entry in resumed.islands[0].tools.memory_refuted
    finally:
        resumed.close()


def test_per_island_files_written(tmp_path):
    p = str(tmp_path / "arch.json")
    eng, _ = _run_engine(persist_path=p)
    for isl in eng.islands:
        ip = str(tmp_path / f"arch.{isl.name}.json")
        assert os.path.exists(ip)
        ln = Lineage.load(ip)
        assert _lineage_fingerprint(ln) == _lineage_fingerprint(isl.lineage)


def test_prefetch_is_pure_cache_warming():
    """prefetch>0 may pay extra speculative evaluations but must leave the
    search itself untouched: identical lineages with and without it."""
    eng_off, _ = _run_engine(n_islands=2)
    eng_on, rep_on = _run_engine(n_islands=2, prefetch=4)
    for a, b in zip(eng_off.islands, eng_on.islands):
        assert _lineage_fingerprint(a.lineage) == _lineage_fingerprint(b.lineage)
    assert rep_on.cache_hits > 0


# -- pipelined stepping (propose -> submit -> harvest) -----------------------------


def test_pipelined_lineages_identical_to_barrier():
    """The tentpole gate: pipelined stepping must commit the same lineages,
    in the same order, as the step-blocking barrier engine — completion
    order of the speculative futures must never show."""
    eng_b, rep_b = _run_engine(check_correctness=False)
    eng_p, rep_p = _run_engine(check_correctness=False, pipeline=True)
    for a, b in zip(eng_b.islands, eng_p.islands):
        assert _lineage_fingerprint(a.lineage) == _lineage_fingerprint(b.lineage)
    assert rep_p.proposed > 0                  # speculation actually happened
    assert rep_b.proposed == 0                 # barrier mode never proposes
    assert rep_p.eval_workers                  # width exposed in the report


def test_pipelined_with_budget_identical_and_budget_respected():
    """The allocator only resizes speculation caps — lineages stay put, and
    the per-epoch caps actually sum to at most the shared budget."""
    eng_b, _ = _run_engine(check_correctness=False)
    eng_p, rep = _run_engine(check_correctness=False, pipeline=True,
                             prefetch_budget=4)
    for a, b in zip(eng_b.islands, eng_p.islands):
        assert _lineage_fingerprint(a.lineage) == _lineage_fingerprint(b.lineage)
    assert sum(isl.prefetch_k for isl in eng_p.islands) <= 4


def test_zero_allocation_means_zero_speculation():
    """An island the allocator floors to 0 must submit NOTHING — an
    allocated zero is a real cap, never 'uncapped' (a 0-budget island
    proposing its full walk would bust the shared budget on its own)."""
    sc = BatchScorer(Scorer(suite=FAST_SUITE, check_correctness=False))
    from repro.core.variation import make_operator
    isl = Island("i", sc, operator=make_operator("avo"))
    isl.step()                                 # bootstrap: candidates exist now
    isl.prefetch_cap = 0                       # allocator assigned zero budget
    assert isl.propose() == 0
    assert isl.proposed == 0
    isl.prefetch_cap = 2                       # a real budget caps the batch
    assert isl.propose() <= 2
    sc.close()


def test_propose_is_pure_speculation():
    """propose() must not advance the search: calling it (even repeatedly)
    before each step leaves the lineage identical to never calling it."""
    sc_a = BatchScorer(Scorer(suite=FAST_SUITE, check_correctness=False))
    sc_b = BatchScorer(Scorer(suite=FAST_SUITE, check_correctness=False))
    from repro.core.variation import make_operator
    plain = Island("plain", sc_a, operator=make_operator("avo"))
    specd = Island("specd", sc_b, operator=make_operator("avo"))
    for _ in range(4):
        plain.step()
        specd.propose()
        specd.propose()                        # double speculation is harmless
        specd.harvest()
    assert _lineage_fingerprint(plain.lineage) == \
        _lineage_fingerprint(specd.lineage)
    assert specd.proposed > 0
    assert specd.supervisor.state() == plain.supervisor.state()
    sc_a.close(); sc_b.close()


def test_propose_noop_on_inline_backend():
    from repro.core import make_backend
    isl = Island("i", make_backend("inline", suite=FAST_SUITE,
                                   check_correctness=False))
    isl.step()                                 # bootstrap commit
    assert isl.propose() == 0                  # nothing to overlap with


def test_gain_profile_peek_only():
    """gain_profile must never pay an evaluation: uncached best -> empty."""
    sc = BatchScorer(Scorer(suite=FAST_SUITE, check_correctness=False))
    isl = Island("i", sc)
    assert isl.gain_profile() == []            # no lineage yet
    isl.step()
    paid = sc.n_evaluations
    prof = isl.gain_profile()
    assert prof == sorted(prof, reverse=True)  # descending gains
    assert sc.n_evaluations == paid            # peeked, not paid
    # simulate a resumed run whose cache is cold: still never pays
    sc.base.cache.clear()
    assert isl.gain_profile() == []
    assert sc.n_evaluations == paid
    sc.close()


# -- the speculative-prefetch budget allocator -------------------------------------


def test_prefetch_allocator_depth_follows_gain_profile():
    from repro.core import PrefetchAllocator
    al = PrefetchAllocator(16)
    assert al.desired_depth([]) == 1           # nothing known: the minimum
    assert al.desired_depth([0.9, 0.5]) == 1   # front-loaded: top edit commits
    deep = al.desired_depth([0.05] * 12)
    assert deep > al.desired_depth([0.4, 0.4, 0.4])


def test_prefetch_allocator_apportionment_deterministic_and_bounded():
    from repro.core import PrefetchAllocator
    al = PrefetchAllocator(6)
    profiles = {"a": [0.9], "b": [0.05] * 10, "c": []}
    alloc = al.allocate(profiles)
    assert sum(alloc.values()) <= 6
    assert alloc == al.allocate(profiles)      # pure function of the profiles
    assert alloc["b"] >= alloc["a"]            # flat profile -> deeper batch
    under = al.allocate({"a": [0.9], "b": [0.9]})
    assert under == {"a": 1, "b": 1}           # under budget: desired depths
    with pytest.raises(ValueError, match="prefetch budget"):
        PrefetchAllocator(0)


def test_toolbelt_evaluate_many_batches_through_scorer():
    batch = BatchScorer(Scorer(suite=FAST_SUITE, check_correctness=False))
    tools = Toolbelt(batch, KnowledgeBase(), Lineage())
    genomes = [seed_genome(), seed_genome().with_(block_q=256), seed_genome()]
    svs = tools.evaluate_many(genomes)
    assert [sv.values for sv in svs] == [batch(g).values for g in genomes]
    assert batch.n_evaluations == 2                 # duplicates collapsed
    assert any(c.tool == "evaluate_many" for c in tools.calls)
    batch.close()


def test_resume_prefers_fresher_per_island_file(tmp_path):
    """A mid-epoch kill leaves per-island files ahead of the aggregate;
    resume must keep the longer per-island history, losing no commit."""
    p = str(tmp_path / "arch.json")
    eng, _ = _run_engine(persist_path=p)
    victim = eng.islands[0]
    agg_len = len(victim.lineage)
    # simulate commits landing after the last barrier: extend ONLY the
    # per-island file
    extended = Lineage.from_payload(victim.lineage.to_payload())
    extra_sv = victim.scorer(seed_genome().with_(block_q=64, block_k=1024))
    extended.update(seed_genome().with_(block_q=64, block_k=1024), extra_sv,
                    "post-barrier commit")
    extended.save(str(tmp_path / f"arch.{victim.name}.json"))

    resumed = IslandEvolution.resume(p, n_islands=3, suite=FAST_SUITE,
                                     migration_interval=2, seed=11)
    try:
        isl0 = next(i for i in resumed.islands if i.name == victim.name)
        assert len(isl0.lineage) == agg_len + 1
        assert isl0.lineage.commits[-1].note == "post-barrier commit"
    finally:
        resumed.close()


def test_coverage_dedupes_islands_sharing_a_suite():
    """Two islands on one suite contribute that suite's configs once, under
    the better island's best genome."""
    sc = BatchScorer(Scorer(suite=FAST_SUITE, check_correctness=False))
    eng = IslandEvolution(specs=[IslandSpec(name="a"), IslandSpec(name="b")],
                          suite=FAST_SUITE, seed=0)
    try:
        # plant different bests on the SAME shared suite
        shared = eng.islands[0].scorer
        weak, strong = seed_genome(), KernelGenome(
            block_q=512, block_k=1024, rescale_mode="branchless",
            mask_mode="block_skip", div_mode="deferred", kv_in_grid=True)
        eng.islands[0].lineage.update(weak, shared(weak), "weak")
        eng.islands[1].lineage.update(strong, shared(strong), "strong")
        vals = eng.coverage_values()
        assert len(vals) == len(FAST_SUITE)          # one contribution, not two
        assert tuple(vals) == shared(strong).values  # the better island owns it
    finally:
        eng.close()
        sc.close()


def test_resume_rejects_history_from_different_suite(tmp_path):
    """Resuming an island under a different target suite must NOT adopt the
    old history: its values/geomeans are incomparable across suites."""
    p = str(tmp_path / "arch.json")
    eng = IslandEvolution(specs=[IslandSpec(name="a", target_suite="mha")],
                          migration_interval=2, seed=1, persist_path=p)
    try:
        eng.run(max_steps=2)
        assert len(eng.islands[0].lineage) > 0
    finally:
        eng.close()

    resumed = IslandEvolution.resume(
        p, specs=[IslandSpec(name="a", target_suite="decode")],
        migration_interval=2, seed=1)
    try:
        assert len(resumed.islands[0].lineage) == 0   # fresh, not mixed
    finally:
        resumed.close()


# -- suite specialization ----------------------------------------------------------


def test_target_suite_threading():
    specs = [IslandSpec(name="mha", target_suite="mha"),
             IslandSpec(name="decode", target_suite="decode")]
    eng = IslandEvolution(specs=specs, migration_interval=2, seed=3)
    try:
        names = {isl.name: tuple(c.name for c in isl.scorer.suite)
                 for isl in eng.islands}
        assert all(n.startswith("mha_") for n in names["mha"])
        assert all(n.startswith("decode_") for n in names["decode"])
        assert eng.scorers["mha"] is not eng.scorers["decode"]
    finally:
        eng.close()


def test_continuous_evolution_target_suite():
    evo = ContinuousEvolution(target_suite="decode")
    assert all(c.name.startswith("decode_") for c in evo.scorer.suite)
