"""Pallas flash-attention kernel vs the pure-jnp oracle (interpret=True).

Sweeps every genome axis, shapes (incl. ragged/padded), dtypes, masking
(causal / sliding-window / softcap), GQA ratios, and the gqa_pack path.
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import mha_reference, flash_reference_blocked

TOL = dict(atol=2e-5, rtol=2e-5)
BTOL = dict(atol=2e-2, rtol=2e-2)   # bf16


def _qkv(seed, B, Hq, Hkv, Sq, Sk, D, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Sk, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Sk, D), dtype)
    return q, k, v


@pytest.mark.parametrize("kv_in_grid", [True, False])
@pytest.mark.parametrize("rescale_mode", ["branchless", "branched"])
@pytest.mark.parametrize("mask_mode", ["dense", "block_skip"])
@pytest.mark.parametrize("div_mode", ["deferred", "eager"])
def test_genome_axes_causal(kv_in_grid, rescale_mode, mask_mode, div_mode):
    q, k, v = _qkv(0, 1, 2, 2, 256, 256, 64)
    ref = mha_reference(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          kv_in_grid=kv_in_grid, rescale_mode=rescale_mode,
                          mask_mode=mask_mode, div_mode=div_mode, interpret=True)
    np.testing.assert_allclose(out, ref, **TOL)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("S", [128, 192, 320])     # incl. non-multiples of block
def test_shapes_padding(causal, S):
    q, k, v = _qkv(1, 2, 4, 4, S, S, 64)
    ref = mha_reference(q, k, v, causal=causal)
    out = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128,
                          interpret=True)
    np.testing.assert_allclose(out, ref, **TOL)


@pytest.mark.parametrize("Hq,Hkv", [(4, 1), (4, 2), (8, 2), (6, 3)])
@pytest.mark.parametrize("gqa_pack", [False, True])
def test_gqa_ratios(Hq, Hkv, gqa_pack):
    q, k, v = _qkv(2, 1, Hq, Hkv, 128, 128, 64)
    ref = mha_reference(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          gqa_pack=gqa_pack, interpret=True)
    np.testing.assert_allclose(out, ref, **TOL)


def test_gqa_pack_wrap_boundary():
    """Packed q rows wrap the true sequence; tiles spanning the wrap must
    still mask correctly (block_q > seq so one tile covers several heads)."""
    q, k, v = _qkv(3, 1, 4, 1, 48, 48, 64)
    ref = mha_reference(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True, block_q=128, block_k=16,
                          gqa_pack=True, interpret=True)
    np.testing.assert_allclose(out, ref, **TOL)


@pytest.mark.parametrize("window", [16, 64, 100])
@pytest.mark.parametrize("mask_mode", ["dense", "block_skip"])
def test_sliding_window(window, mask_mode):
    q, k, v = _qkv(4, 1, 2, 2, 192, 192, 64)
    ref = mha_reference(q, k, v, causal=True, window=window)
    out = flash_attention(q, k, v, causal=True, window=window, block_q=64,
                          block_k=64, mask_mode=mask_mode, interpret=True)
    np.testing.assert_allclose(out, ref, **TOL)


def test_softcap():
    q, k, v = _qkv(5, 1, 2, 2, 128, 128, 64)
    ref = mha_reference(q, k, v, causal=True, softcap=50.0)
    out = flash_attention(q, k, v, causal=True, softcap=50.0, block_q=64,
                          block_k=64, interpret=True)
    np.testing.assert_allclose(out, ref, **TOL)


def test_bf16():
    q, k, v = _qkv(6, 1, 2, 2, 128, 128, 128, jnp.bfloat16)
    ref = mha_reference(q, k, v, causal=True).astype(jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True).astype(jnp.float32)
    np.testing.assert_allclose(out, ref, **BTOL)


def test_cross_attention_shapes():
    """Sq != Sk (decoder cross-attn in seamless-m4t)."""
    q, k, v = _qkv(7, 2, 4, 4, 96, 160, 64)
    ref = mha_reference(q, k, v, causal=False)
    out = flash_attention(q, k, v, causal=False, block_q=32, block_k=64,
                          interpret=True)
    np.testing.assert_allclose(out, ref, **TOL)


@pytest.mark.parametrize("kv_in_grid", [True, False])
def test_bf16_accumulator_degrades_accuracy(kv_in_grid):
    """acc_dtype=bf16 must run, but with error well above the correctness
    tolerance — the axis exists to exercise the scoring gate."""
    q, k, v = _qkv(13, 1, 2, 2, 160, 160, 64)
    ref = mha_reference(q, k, v, causal=True)
    good = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                           kv_in_grid=kv_in_grid, acc_dtype="f32",
                           interpret=True)
    bad = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                          kv_in_grid=kv_in_grid, acc_dtype="bf16",
                          interpret=True)
    assert float(jnp.abs(good - ref).max()) < 2e-5
    assert float(jnp.abs(bad - ref).max()) > 1e-4
    assert np.isfinite(np.asarray(bad)).all()


def test_numerically_extreme_scores():
    """Online softmax must survive large score magnitudes (running-max path)."""
    q, k, v = _qkv(8, 1, 2, 2, 128, 128, 64)
    q = q * 30.0
    ref = mha_reference(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


def test_blocked_reference_matches_naive():
    """The dry-run fallback implements identical math to the oracle."""
    q, k, v = _qkv(9, 2, 4, 2, 200, 200, 64)
    for causal in (False, True):
        for window in (None, 64):
            ref = mha_reference(q, k, v, causal=causal, window=window)
            out = flash_reference_blocked(q, k, v, causal=causal, window=window,
                                          block_k=64)
            np.testing.assert_allclose(out, ref, **TOL)


@pytest.mark.parametrize("window,cq,S", [(32, 64, 256), (64, 64, 256),
                                         (100, 128, 384)])
def test_banded_swa_reference(window, cq, S):
    """The q-chunked banded SWA path must equal the naive oracle."""
    from repro.kernels.ref import flash_reference_banded
    q, k, v = _qkv(11, 2, 4, 2, S, S, 64)
    ref = mha_reference(q, k, v, causal=True, window=window)
    out = flash_reference_banded(q, k, v, window=window, chunk_q=cq)
    np.testing.assert_allclose(out, ref, **TOL)


def test_banded_swa_with_softcap():
    from repro.kernels.ref import flash_reference_banded
    q, k, v = _qkv(12, 1, 2, 2, 256, 256, 64)
    ref = mha_reference(q, k, v, causal=True, window=48, softcap=30.0)
    out = flash_reference_banded(q, k, v, window=48, softcap=30.0, chunk_q=64)
    np.testing.assert_allclose(out, ref, **TOL)


def test_blocked_reference_q_offset():
    """Suffix-scoring (q_offset) used by chunked prefill."""
    q, k, v = _qkv(10, 1, 2, 2, 128, 128, 64)
    full = mha_reference(q, k, v, causal=True)
    tail = flash_reference_blocked(q[:, :, 96:], k, v, causal=True,
                                   block_k=32, q_offset=96)
    np.testing.assert_allclose(tail, full[:, :, 96:], **TOL)


def _oracle_cases(n=20, rng_seed=0):
    """Deterministic seeded sample of the genome x shape space (replaces the
    old hypothesis strategy with the same coverage, no runtime dependency)."""
    r = random.Random(rng_seed)
    cases = []
    for _ in range(n):
        cases.append((
            r.randrange(2**16),                          # seed
            r.randint(1, 2),                             # B
            r.randint(1, 4),                             # hq_mult
            r.randint(1, 2),                             # Hkv
            r.choice([64, 96, 128, 160]),                # S
            r.choice([32, 64]),                          # D
            r.choice([False, True]),                     # causal
            r.choice([32, 64, 128]),                     # bq
            r.choice([32, 64, 128]),                     # bk
            r.choice(["branchless", "branched"]),        # rescale
            r.choice(["dense", "block_skip"]),           # mask
            r.choice([False, True]),                     # kv_in_grid
        ))
    return cases


@pytest.mark.parametrize(
    "seed,B,hq_mult,Hkv,S,D,causal,bq,bk,rescale,mask,kv_in_grid",
    _oracle_cases())
def test_property_kernel_matches_oracle(seed, B, hq_mult, Hkv, S, D, causal,
                                        bq, bk, rescale, mask, kv_in_grid):
    """Property: ANY genome point must agree with the oracle on ANY shape —
    the correctness gate of the scoring function f is exactly this."""
    Hq = Hkv * hq_mult
    q, k, v = _qkv(seed, B, Hq, Hkv, S, S, D)
    ref = mha_reference(q, k, v, causal=causal)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          rescale_mode=rescale, mask_mode=mask,
                          kv_in_grid=kv_in_grid, interpret=True)
    np.testing.assert_allclose(out, ref, atol=5e-5, rtol=5e-5)
