"""flash_decode (single-token KV-cache attention) vs decode_reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import flash_decode
from repro.kernels.ref import decode_reference

TOL = dict(atol=3e-5, rtol=3e-5)


def _inputs(seed, B, Hq, Hkv, L, D):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, Hkv, L, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, Hkv, L, D), jnp.float32)
    vl = jax.random.randint(ks[3], (B,), 1, L + 1, jnp.int32)
    return q, kc, vc, vl


@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (8, 2), (4, 1)])
@pytest.mark.parametrize("L", [256, 384, 512])
def test_decode_matches_reference(Hq, Hkv, L):
    q, kc, vc, vl = _inputs(0, 2, Hq, Hkv, L, 64)
    ref = decode_reference(q, kc, vc, vl)
    out = flash_decode(q, kc, vc, vl, block_k=128, interpret=True)
    np.testing.assert_allclose(out, ref, **TOL)


def test_decode_full_and_single_token_cache():
    q, kc, vc, _ = _inputs(1, 2, 4, 2, 256, 64)
    full = jnp.full((2,), 256, jnp.int32)
    one = jnp.ones((2,), jnp.int32)
    np.testing.assert_allclose(
        flash_decode(q, kc, vc, full, block_k=128, interpret=True),
        decode_reference(q, kc, vc, full), **TOL)
    np.testing.assert_allclose(
        flash_decode(q, kc, vc, one, block_k=128, interpret=True),
        decode_reference(q, kc, vc, one), **TOL)


def test_decode_softcap():
    q, kc, vc, vl = _inputs(2, 1, 4, 4, 256, 64)
    ref = decode_reference(q, kc, vc, vl, softcap=30.0)
    out = flash_decode(q, kc, vc, vl, softcap=30.0, block_k=128, interpret=True)
    np.testing.assert_allclose(out, ref, **TOL)


def test_decode_equals_last_row_of_prefill_attention():
    """Decoding token t must equal row t of full causal attention."""
    from repro.kernels.ref import mha_reference
    B, H, S, D = 1, 4, 96, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, S, D), jnp.float32)
    full = mha_reference(q, k, v, causal=True)
    out = flash_decode(q[:, :, -1], k, v, jnp.full((B,), S, jnp.int32),
                       block_k=32, interpret=True)
    np.testing.assert_allclose(out, full[:, :, -1], **TOL)
