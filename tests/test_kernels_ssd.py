"""Mamba-2 SSD kernels: chunked Pallas kernel and chunked-jnp reference vs the
naive sequential recurrence oracle; decode step vs recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import (ssd_reference, ssd_chunked_reference,
                               ssd_decode_reference)
from repro.kernels.ssd import ssd_chunked

TOL = dict(atol=2e-4, rtol=2e-4)


def _inputs(seed, B, L, H, P, G, N):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, L, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.5)
    Bm = jax.random.normal(ks[3], (B, L, G, N), jnp.float32) * 0.5
    Cm = jax.random.normal(ks[4], (B, L, G, N), jnp.float32) * 0.5
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_chunked_reference_matches_recurrence(chunk):
    x, dt, A, Bm, Cm = _inputs(0, 2, 128, 4, 16, 1, 16)
    y_ref, h_ref = ssd_reference(x, dt, A, Bm, Cm)
    y, h = ssd_chunked_reference(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(y, y_ref, **TOL)
    np.testing.assert_allclose(h, h_ref, **TOL)


@pytest.mark.parametrize("H,bh", [(4, 4), (8, 4), (8, 8)])
def test_pallas_ssd_matches_recurrence(H, bh):
    x, dt, A, Bm, Cm = _inputs(1, 1, 128, H, 16, 1, 16)
    y_ref, h_ref = ssd_reference(x, dt, A, Bm, Cm)
    y, h = ssd_chunked(x, dt, A, Bm, Cm, chunk=32, block_heads=bh,
                       interpret=True)
    np.testing.assert_allclose(y, y_ref, **TOL)
    np.testing.assert_allclose(h, h_ref, **TOL)


def test_pallas_ssd_chunk_invariance():
    x, dt, A, Bm, Cm = _inputs(2, 1, 128, 4, 16, 1, 16)
    y32, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=32, block_heads=4,
                         interpret=True)
    y64, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=64, block_heads=4,
                         interpret=True)
    np.testing.assert_allclose(y32, y64, **TOL)


def test_group_broadcast():
    """G > 1 groups broadcast over heads (chunked reference path)."""
    x, dt, A, Bm, Cm = _inputs(3, 1, 64, 8, 16, 2, 16)
    y_ref, _ = ssd_reference(x, dt, A, Bm, Cm)
    y, _ = ssd_chunked_reference(x, dt, A, Bm, Cm, chunk=16)
    np.testing.assert_allclose(y, y_ref, **TOL)


def test_decode_step_matches_recurrence():
    """Running the per-token decode over L steps == the full recurrence."""
    B, L, H, P, G, N = 1, 16, 4, 8, 1, 8
    x, dt, A, Bm, Cm = _inputs(4, B, L, H, P, G, N)
    y_ref, h_ref = ssd_reference(x, dt, A, Bm, Cm)
    state = jnp.zeros((B, H, P, N), jnp.float32)
    ys = []
    for t in range(L):
        y_t, state = ssd_decode_reference(x[:, t], dt[:, t], A,
                                          Bm[:, t], Cm[:, t], state)
        ys.append(y_t)
    np.testing.assert_allclose(jnp.stack(ys, 1), y_ref, **TOL)
    np.testing.assert_allclose(state, h_ref, **TOL)


def test_initial_state_carry():
    """Chunked reference with init_state == continuing the recurrence."""
    x, dt, A, Bm, Cm = _inputs(5, 1, 64, 4, 8, 1, 8)
    y_full, h_full = ssd_reference(x, dt, A, Bm, Cm)
    _, h_half = ssd_reference(x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32])
    y2, h2 = ssd_chunked_reference(x[:, 32:], dt[:, 32:], A, Bm[:, 32:],
                                   Cm[:, 32:], chunk=16, init_state=h_half)
    np.testing.assert_allclose(y2, y_full[:, 32:], **TOL)
    np.testing.assert_allclose(h2, h_full, **TOL)
