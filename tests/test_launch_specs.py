"""input_specs/state-spec stand-ins: correct shapes/dtypes, zero allocation,
and shardable on a (1,1) mesh in-process (the 512-device meshes are exercised
by the dry-run subprocess; see EXPERIMENTS.md §Dry-run)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES_BY_NAME
from repro.configs.registry import ARCHS, get_arch
from repro.launch import specs as S


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_batch_specs_shapes(mesh):
    cfg = get_arch("qwen2-7b")
    cell = SHAPES_BY_NAME["train_4k"]
    b = S.batch_specs(cfg, cell, mesh)
    assert b["tokens"].shape == (256, 4096) and b["tokens"].dtype == jnp.int32
    assert b["labels"].shape == (256, 4096)
    assert all(isinstance(x, jax.ShapeDtypeStruct) for x in b.values())


def test_batch_specs_modality_extras(mesh):
    cell = SHAPES_BY_NAME["train_4k"]
    vlm = S.batch_specs(get_arch("phi-3-vision-4.2b"), cell, mesh)
    assert "prefix_embeds" in vlm
    aud = S.batch_specs(get_arch("seamless-m4t-medium"), cell, mesh)
    assert "enc_frames" in aud and aud["enc_frames"].shape[-1] == 1024


def test_param_specs_no_allocation(mesh):
    cfg = get_arch("mixtral-8x22b")      # 140B params — must NOT allocate
    sds, sh = S.param_specs(cfg, mesh)
    leaves = jax.tree_util.tree_leaves(sds)
    assert all(isinstance(x, jax.ShapeDtypeStruct) for x in leaves)
    total = sum(x.size for x in leaves)
    assert total > 100e9                  # the full config, abstractly


def test_cache_specs_decode(mesh):
    cfg = get_arch("qwen2-7b")
    cell = SHAPES_BY_NAME["decode_32k"]
    cache = S.cache_specs(cfg, cell, mesh)
    leaves = jax.tree_util.tree_leaves(cache)
    assert leaves and all(isinstance(x, jax.ShapeDtypeStruct) for x in leaves)


def test_every_cell_has_specs(mesh):
    from repro.configs.base import cells_for
    for name in ARCHS:
        cfg = get_arch(name)
        for cell in cells_for(name):
            b = S.batch_specs(cfg, cell, mesh)
            assert b["tokens"].shape == (cell.global_batch, cell.seq_len)
