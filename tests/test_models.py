"""Per-architecture smoke tests (reduced configs): forward/train shapes,
finiteness, determinism; arch-specific behaviours (softcap, SWA, MoE routing,
SSD recurrence, enc-dec, vision prefix)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.data.pipeline import TokenPipeline
from repro.models import init_params, lm_logits, lm_loss


def _batch(cfg, B=2, S=32, seed=0):
    pipe = TokenPipeline(cfg, seq_len=S, global_batch=B, seed=seed)
    return {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_shapes_and_finiteness(name, tiny_archs):
    cfg = tiny_archs[name]
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = lm_logits(params, cfg, batch["tokens"],
                       compute_dtype=jnp.float32,
                       **{k: batch[k] for k in ("prefix_embeds", "enc_frames")
                          if k in batch})
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_grads_finite(name, tiny_archs):
    cfg = tiny_archs[name]
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: lm_loss(p, cfg, batch, compute_dtype=jnp.float32))(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in leaves)
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves)


def test_forward_deterministic(tiny_archs):
    cfg = tiny_archs["qwen2-7b"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    a = lm_logits(params, cfg, batch["tokens"], compute_dtype=jnp.float32)
    b = lm_logits(params, cfg, batch["tokens"], compute_dtype=jnp.float32)
    np.testing.assert_array_equal(a, b)


def test_causality(tiny_archs):
    """Future tokens must not influence past logits (decoder-only archs)."""
    for name in ("qwen2-7b", "mamba2-780m", "jamba-v0.1-52b", "gemma2-27b"):
        cfg = tiny_archs[name]
        params = init_params(cfg, jax.random.PRNGKey(1))
        t = jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab_size, (1, 24)), jnp.int32)
        t2 = t.at[:, 20:].set((t[:, 20:] + 7) % cfg.vocab_size)
        la = lm_logits(params, cfg, t, compute_dtype=jnp.float32)
        lb = lm_logits(params, cfg, t2, compute_dtype=jnp.float32)
        np.testing.assert_allclose(la[:, :20], lb[:, :20], atol=1e-4,
                                   err_msg=name)


def test_logit_softcap_bounds_gemma2(tiny_archs):
    cfg = tiny_archs["gemma2-27b"]
    assert cfg.logit_softcap == 30.0
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = lm_logits(params, cfg, batch["tokens"], compute_dtype=jnp.float32)
    assert float(jnp.abs(logits).max()) <= 30.0


def test_swa_limits_context(tiny_archs):
    """h2o-danube (SWA): token far outside every window cannot influence the
    final logits; a full-attention arch does feel it."""
    cfg = tiny_archs["h2o-danube-3-4b"]
    w = max(b.window or 0 for b in cfg.pattern)
    assert w > 0
    # NOTE: with interleaved full-attn layers info still propagates; make a
    # pure-SWA variant to isolate the window.
    import dataclasses
    pure = dataclasses.replace(
        cfg, pattern=tuple(dataclasses.replace(b, window=8) for b in cfg.pattern))
    params = init_params(pure, jax.random.PRNGKey(0))
    S = 40
    t = jnp.asarray(np.random.default_rng(1).integers(
        0, pure.vocab_size, (1, S)), jnp.int32)
    t2 = t.at[:, 0].set((t[:, 0] + 3) % pure.vocab_size)
    la = lm_logits(params, pure, t, compute_dtype=jnp.float32)
    lb = lm_logits(params, pure, t2, compute_dtype=jnp.float32)
    # receptive field after 4 layers of window 8 = 4*(8-1); position 39 > 28
    np.testing.assert_allclose(la[:, -1], lb[:, -1], atol=1e-4)


def test_moe_router_uses_topk(tiny_archs):
    """Changing a non-selected expert's weights must not change outputs."""
    cfg = tiny_archs["mixtral-8x22b"]
    assert cfg.moe.top_k < cfg.moe.n_experts
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, B=1, S=8)
    base = lm_logits(params, cfg, batch["tokens"], compute_dtype=jnp.float32)
    assert np.isfinite(np.asarray(base)).all()


def test_vision_prefix_influences_output(tiny_archs):
    cfg = tiny_archs["phi-3-vision-4.2b"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    assert "prefix_embeds" in batch
    a = lm_logits(params, cfg, batch["tokens"],
                  prefix_embeds=batch["prefix_embeds"], compute_dtype=jnp.float32)
    b = lm_logits(params, cfg, batch["tokens"],
                  prefix_embeds=batch["prefix_embeds"] * 2.0,
                  compute_dtype=jnp.float32)
    assert float(jnp.abs(a - b).max()) > 1e-6


def test_encdec_encoder_influences_decoder(tiny_archs):
    cfg = tiny_archs["seamless-m4t-medium"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    # NOTE: +const / *scale perturbations are invisible to LayerNorm models
    # by construction; perturb with structured noise instead.
    noise = jax.random.normal(jax.random.PRNGKey(9),
                              batch["enc_frames"].shape, jnp.float32)
    a = lm_logits(params, cfg, batch["tokens"], enc_frames=batch["enc_frames"],
                  compute_dtype=jnp.float32)
    b = lm_logits(params, cfg, batch["tokens"],
                  enc_frames=batch["enc_frames"] + noise, compute_dtype=jnp.float32)
    assert float(jnp.abs(a - b).max()) > 1e-6


def test_bf16_forward_close_to_f32(tiny_archs):
    cfg = tiny_archs["qwen2-7b"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    f32 = lm_logits(params, cfg, batch["tokens"], compute_dtype=jnp.float32)
    bf = lm_logits(params, cfg, batch["tokens"], compute_dtype=jnp.bfloat16)
    assert float(jnp.mean(jnp.abs(f32 - bf.astype(jnp.float32)))) < 0.15
