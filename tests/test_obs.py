"""The unified telemetry plane (repro.core.obs): bounded event ring, metrics
registry, trace propagation end-to-end over the service wire (negotiated like
compact/shm — legacy workers see byte-identical frames), the JSONL run
journal + report CLI, and the plane's two hard guarantees — zero-cost when
disabled, lineage-inert when enabled (bit-identical lineages obs off vs on
across every eval backend)."""
import concurrent.futures as cf
import json
import socket
import threading

import pytest

from repro.core import IslandEvolution, Scorer, obs, seed_genome
from repro.core.evals import EvalCoordinator, EvalSpec, protocol
from repro.core.evals.elastic import ElasticProcessPool
from repro.core.evals.service import _worker_env
from repro.core.obs import report
from repro.core.perfmodel import BenchConfig

FAST_SUITE = [BenchConfig("c4k", 8, 16, 16, 4096, causal=True),
              BenchConfig("n4k", 8, 16, 16, 4096, causal=False)]


@pytest.fixture
def obs_on(tmp_path):
    """Enable telemetry for one test, journal into tmp, restore after."""
    prev = obs.enabled()
    obs.set_enabled(True)
    obs.BUS.ring.clear()
    yield tmp_path
    obs.close_journal()
    obs.set_enabled(prev)
    obs.BUS.ring.clear()


# -- the bounded event ring --------------------------------------------------------


def test_ring_bounds_and_counts_drops():
    r = obs.EventRing(cap=3)
    for i in range(5):
        r.append({"i": i})
    assert len(r) == 3
    assert r.dropped == 2
    assert [e["i"] for e in r] == [2, 3, 4]     # newest survive


def test_ring_quacks_like_the_list_it_replaced():
    r = obs.EventRing(cap=8)
    assert not r                                 # empty ring is falsy
    r.append({"event": "join"})
    r.append({"event": "leave"})
    assert r and len(r) == 2
    assert r[0]["event"] == "join" and r[-1]["event"] == "leave"
    assert [e["event"] for e in r[1:]] == ["leave"]          # slice view
    assert sorted(r, key=lambda e: e["event"])[0]["event"] == "join"
    assert list(r) == r.snapshot()
    with pytest.raises(ValueError):
        obs.EventRing(cap=0)


def test_coordinator_event_window_is_bounded(monkeypatch):
    monkeypatch.setenv("REPRO_OBS_EVENT_CAP", "1")
    coord = EvalCoordinator()
    socks = []
    try:
        for i in range(3):
            s = socket.create_connection(coord.address)
            socks.append(s)
            protocol.send_msg(s, {"type": protocol.HELLO, "name": f"w{i}",
                                  "slots": 1})
            assert protocol.recv_msg(s)["type"] == protocol.WELCOME
        assert coord.wait_for_workers(3, timeout=10)
        st = coord.stats()
        assert len(st["events"]) == 1            # window capped
        assert st["events_dropped"] >= 2         # shed joins are counted
        assert st["joined"] == 3                 # ...but totals are counters
    finally:
        for s in socks:
            s.close()
        coord.close()


def test_engine_commit_window_bounded_and_reported(monkeypatch):
    monkeypatch.setenv("REPRO_OBS_COMMIT_CAP", "2")
    eng = IslandEvolution(n_islands=2, suite=FAST_SUITE, seed=11,
                          migration_interval=2, check_correctness=False)
    try:
        rep = eng.run(max_steps=4)
    finally:
        eng.close()
    assert len(eng.commit_events) <= 2
    assert rep.commit_events_dropped == eng.commit_events.dropped
    if rep.commits > 2:
        assert rep.commit_events_dropped >= rep.commits - 2


# -- the metrics registry ----------------------------------------------------------


def test_registry_get_or_create_identity_and_kind_guard():
    reg = obs.MetricsRegistry()
    a = reg.counter("evals", island="i0")
    b = reg.counter("evals", island="i0")
    assert a is b                                # one instrument per key
    a.inc()
    a.inc(3)
    assert b.value == 4
    assert reg.counter("evals", island="i1").value == 0   # labels split
    with pytest.raises(TypeError):
        reg.gauge("evals", island="i0")          # same name, wrong kind
    g = reg.gauge("depth")
    g.set(7)
    h = reg.histogram("lat")
    for v in (1.0, 3.0):
        h.observe(v)
    assert (h.count, h.total, h.min, h.max, h.mean) == (2, 4.0, 1.0, 3.0, 2.0)
    snap = {(s["name"], tuple(sorted(s.get("labels", {}).items())))
            for s in reg.snapshot()}
    assert ("evals", (("island", "i0"),)) in snap
    reg.reset()
    assert reg.snapshot() == []


def test_legacy_stats_surfaces_read_the_registry():
    sc = Scorer(suite=FAST_SUITE, check_correctness=False)
    g = seed_genome()
    sc(g)
    sc(g)
    assert (sc.cache.misses, sc.cache.hits) == (1, 1)   # property view
    stats = sc.cache.stats()
    assert stats["hits"] == 1 and stats["misses"] == 1


# -- trace propagation -------------------------------------------------------------


def test_trace_binding_nests_and_restores():
    assert obs.current_trace() is None
    t1, t2 = obs.new_trace(), obs.new_trace()
    assert t1 != t2
    with obs.use_trace(t1):
        assert obs.current_trace() == t1
        with obs.use_trace(t2):
            assert obs.current_trace() == t2
        assert obs.current_trace() == t1
    assert obs.current_trace() is None


def test_console_sink_prints_narration_only(obs_on, capsys):
    obs.span("score", obs.new_trace(), dur_s=0.5)
    obs.narrate("[epoch 3] best=12.0 TFLOPS")
    out = capsys.readouterr().out
    assert "[epoch 3] best=12.0 TFLOPS" in out
    assert "score" not in out                    # spans stay off the console


def test_worker_env_propagates_obs_toggle():
    prev = obs.enabled()
    try:
        obs.set_enabled(True)
        assert _worker_env()["REPRO_OBS"] == "1"
        obs.set_enabled(False)
        assert _worker_env()["REPRO_OBS"] == "0"
    finally:
        obs.set_enabled(prev)


# -- the wire: capability-negotiated tracing ---------------------------------------


def _hello(sock, **caps):
    protocol.send_msg(sock, {"type": protocol.HELLO, "slots": 2,
                             "host": "elsewhere", **caps})
    assert protocol.recv_msg(sock)["type"] == protocol.WELCOME


def test_legacy_worker_never_sees_a_trace_field(obs_on):
    """A worker that does not advertise ``trace`` receives frames with no
    trace key even while the submitter traces — same negotiation contract
    as compact/shm, so pre-trace binaries are untouched."""
    spec = EvalSpec.resolve(FAST_SUITE, check_correctness=False)
    genomes = [seed_genome().with_(block_q=bq) for bq in (64, 256)]
    coord = EvalCoordinator()
    legacy = socket.create_connection(coord.address)
    compact = None
    try:
        _hello(legacy, name="old")               # no compact, no trace
        assert coord.wait_for_workers(1, timeout=10)
        coord.submit_many(spec, genomes, trace=obs.new_trace())
        for _ in genomes:
            msg = protocol.recv_msg(legacy)
            assert msg["type"] == protocol.TASK
            assert "trace" not in msg
        legacy.close()
        legacy = None

        compact = socket.create_connection(coord.address)
        _hello(compact, name="mid", compact=True)   # compact but no trace
        assert coord.wait_for_workers(1, timeout=10)
        coord.submit_many(spec, genomes, trace=obs.new_trace())
        msg = protocol.recv_msg(compact)
        assert msg["type"] == protocol.TASKS
        assert "trace" not in msg
    finally:
        for s in (legacy, compact):
            if s is not None:
                s.close()
        coord.close()


def test_traced_frames_carry_the_map_and_untraced_none(obs_on):
    spec = EvalSpec.resolve(FAST_SUITE, check_correctness=False)
    coord = EvalCoordinator()
    s = socket.create_connection(coord.address)
    try:
        _hello(s, name="new", compact=True, trace=True)
        assert coord.wait_for_workers(1, timeout=10)
        tr = obs.new_trace()
        coord.submit(spec, seed_genome().with_(block_q=64), trace=tr)
        msg = protocol.recv_msg(s)
        assert msg["type"] == protocol.TASKS
        (tid, _payload), = msg["tasks"]
        assert dict(msg["trace"]) == {tid: (tr, 0)}
        # an untraced submission to the same capable worker carries no map
        coord.submit(spec, seed_genome().with_(block_q=256), trace=None)
        msg2 = protocol.recv_msg(s)
        assert "trace" not in msg2
    finally:
        s.close()
        coord.close()


def test_spans_stitch_across_worker_death_and_requeue(obs_on):
    """The SIGKILL-shaped fault path: worker A takes a traced task and dies
    holding it; the task requeues (attempt 1) onto worker B, which returns
    spans.  The journal/ring must show BOTH dispatch attempts, the requeue,
    and B's worker-side spans — one stitched eval timeline."""
    spec = EvalSpec.resolve(FAST_SUITE, check_correctness=False)
    coord = EvalCoordinator(heartbeat_s=0.2)
    a = socket.create_connection(coord.address)
    b = None
    try:
        _hello(a, name="doomed", compact=True, trace=True)
        assert coord.wait_for_workers(1, timeout=10)
        tr = obs.new_trace()
        fut = coord.submit(spec, seed_genome().with_(block_q=128), trace=tr)
        msg = protocol.recv_msg(a)
        (tid, _payload), = msg["tasks"]
        assert dict(msg["trace"])[tid] == (tr, 0)
        a.close()                                # synchronous death, task held
        a = None

        b = socket.create_connection(coord.address)
        _hello(b, name="savior", compact=True, trace=True)
        msg = protocol.recv_msg(b)               # the requeued task
        (tid2, _payload), = msg["tasks"]
        assert dict(msg["trace"])[tid2] == (tr, 1)   # second attempt
        protocol.send_msg(b, {
            "type": protocol.RESULT, "id": tid2, "ok": True, "value": "sv",
            "spans": ({"span": "deserialize", "dur_s": 0.001},
                      {"span": "score", "dur_s": 0.25, "rung": "perfmodel"})})
        assert fut.result(10) == "sv"

        evs = [e for e in obs.BUS.ring.snapshot() if e.get("trace") == tr]
        dispatches = [e for e in evs if e.get("span") == "dispatch"]
        assert [(d["worker"], d["attempt"]) for d in dispatches] == \
            [("doomed", 0), ("savior", 1)]
        assert any(e.get("span") == "requeue" and e["attempt"] == 1
                   for e in evs)
        score = next(e for e in evs if e.get("span") == "score")
        assert (score["worker"], score["attempt"]) == ("savior", 1)
        assert score["rung"] == "perfmodel"
        st = coord.stats()
        assert st["tasks_requeued"] == 1 and st["tasks_completed"] == 1
    finally:
        for s in (a, b):
            if s is not None:
                s.close()
        coord.close()


# -- journal + report CLI ----------------------------------------------------------


def test_journal_roundtrip_and_report_cli(obs_on, capsys):
    path = obs.ensure_journal(run_id="t-report", root=str(obs_on))
    tr = obs.new_trace()
    obs.span("submit", tr, backend="thread", n=1)
    obs.span("score", tr, dur_s=0.25, rung="perfmodel")
    obs.publish("commit", trace=tr, island="island0", geomean=12.5)
    obs.narrate("[step 0] committed=True")
    obs.close_journal()

    events = report.load_journal(path)
    s = report.summarize(events)
    assert s["kinds"]["span"] == 2 and s["kinds"]["commit"] == 1
    assert s["kinds"]["narrate"] == 1
    assert s["traces"] == 1
    assert s["islands"]["island0"] == {"commits": 1, "best": 12.5}

    assert report.main([str(path)]) == 0
    out = capsys.readouterr().out
    assert tr in out and "submit" in out and "commit" in out
    assert report.main([str(obs_on / "nope.jsonl")]) == 2


def test_journal_tolerates_a_torn_tail_line(obs_on):
    path = obs.ensure_journal(run_id="t-torn", root=str(obs_on))
    obs.publish("commit", island="i0", geomean=1.0)
    obs.close_journal()
    with open(path, "a", encoding="utf-8") as fh:
        fh.write('{"event": "commit", "isl')      # killed writer mid-line
    events = report.load_journal(path)
    assert [e["event"] for e in events if e["event"] != "journal_open"] \
        == ["commit"]


def test_ensure_journal_noop_when_disabled(tmp_path):
    prev = obs.enabled()
    obs.set_enabled(False)
    try:
        assert obs.ensure_journal(run_id="x", root=str(tmp_path)) is None
        assert obs.journal_path() is None
        assert not (tmp_path / "x").exists()
    finally:
        obs.set_enabled(prev)


# -- resize/attach-failure events on the bus ---------------------------------------


def test_elastic_pool_resizes_publish_bus_events(obs_on):
    pool = ElasticProcessPool(
        slot_factory=lambda: cf.ThreadPoolExecutor(max_workers=1),
        min_workers=1, max_workers=3, grow_depth=0.5, hysteresis=1,
        shrink_idle_s=3600.0)
    gate = threading.Event()
    try:
        futs = [pool.submit(gate.wait, 10) for _ in range(6)]
        gate.set()
        for f in futs:
            f.result(10)
    finally:
        pool.shutdown(wait=True)
    grows = [e for e in obs.BUS.ring.snapshot() if e["event"] == "pool_grow"]
    assert grows, "growth must be mirrored onto the bus"
    assert all("depth" in e["why"] and e["workers"] >= 2 for e in grows)
    assert pool.stats()["grown"] == len(grows)   # same log, two surfaces


# -- the hard constraint: lineage-inert when enabled --------------------------------


IDENTITY_BACKENDS = ("inline", "thread", "process", "service")


def _fingerprints(**kw):
    eng = IslandEvolution(n_islands=2, suite=FAST_SUITE, seed=11,
                          migration_interval=2, check_correctness=False, **kw)
    try:
        eng.run(max_steps=4)
        return [[(c.genome.key(), round(c.geomean, 9), c.note)
                 for c in isl.lineage.commits] for isl in eng.islands]
    finally:
        eng.close()


@pytest.mark.parametrize("backend", IDENTITY_BACKENDS)
def test_lineages_bit_identical_obs_off_vs_on(backend, obs_on):
    kw = {"backend": backend}
    if backend == "service":
        kw["service_workers"] = 1
    obs.set_enabled(False)
    off = _fingerprints(**kw)
    obs.set_enabled(True)
    path = obs.ensure_journal(run_id=f"t-ident-{backend}", root=str(obs_on))
    on = _fingerprints(**kw)
    assert off == on
    # the enabled run actually observed: its journal holds the commits
    obs.close_journal()
    events = report.load_journal(path)
    assert any(e.get("event") == "commit" for e in events)
