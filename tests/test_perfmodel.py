"""Qualitative properties of the TPU v5e analytic performance model — the
throughput axis of the AVO scoring function f."""
import itertools
import math
import random

import pytest

from repro.core.perfmodel import (BenchConfig, EXPERT_GENOME, estimate,
                                  expert_reference, fa_reference, gqa_suite,
                                  mha_suite, useful_flops, vmem_usage,
                                  PEAK_FLOPS, VMEM_BYTES)
from repro.core.search_space import KernelGenome, seed_genome

CFG = BenchConfig("t", batch=1, n_heads=16, n_kv_heads=16, seq_len=8192,
                  causal=True)
CFG_NC = BenchConfig("t", batch=1, n_heads=16, n_kv_heads=16, seq_len=8192,
                     causal=False)
GOOD = EXPERT_GENOME


def test_deterministic():
    a, b = estimate(GOOD, CFG), estimate(GOOD, CFG)
    assert a.tflops == b.tflops and a.total_s == b.total_s


def test_never_exceeds_roofline():
    for g in (seed_genome(), GOOD,
              KernelGenome(block_q=256, block_k=256, kv_in_grid=True)):
        for cfg in mha_suite() + gqa_suite():
            p = estimate(g, cfg)
            if p.feasible:
                assert p.tflops * 1e12 <= PEAK_FLOPS * 1.0001
                assert p.fraction_of_roofline <= 1.0001


def test_vmem_overflow_is_infeasible():
    # staging full K/V (kv_in_grid=False) at 256k seq: 134 MiB > 128 MiB VMEM
    g = KernelGenome(block_q=512, block_k=512, kv_in_grid=False)
    cfg = BenchConfig("t", 1, 16, 16, 262144, head_dim=128, causal=False)
    p = estimate(g, cfg)
    assert vmem_usage(g, cfg) > VMEM_BYTES
    assert not p.feasible and p.tflops == 0.0
    assert "VMEM" in p.infeasible_reason


def test_block_skip_beats_dense_on_causal():
    dense = estimate(GOOD.with_(mask_mode="dense"), CFG)
    skip = estimate(GOOD.with_(mask_mode="block_skip"), CFG)
    assert skip.total_s < dense.total_s


def test_mask_mode_irrelevant_when_noncausal_is_small():
    """Non-causal has no skippable blocks; modes should be close."""
    dense = estimate(GOOD.with_(mask_mode="dense"), CFG_NC)
    skip = estimate(GOOD.with_(mask_mode="block_skip"), CFG_NC)
    assert abs(dense.total_s - skip.total_s) / dense.total_s < 0.30


def test_branchless_beats_branched_noncausal():
    """Paper §5.1: the branch bubble dominates the multiply-by-one cost on
    fully unmasked iterations (non-causal)."""
    br = estimate(GOOD.with_(rescale_mode="branched"), CFG_NC)
    bl = estimate(GOOD.with_(rescale_mode="branchless"), CFG_NC)
    assert bl.total_s < br.total_s


def test_pipeline_overlap_helps():
    """Paper §5.2 analogue: kv_in_grid pipelining beats the serial loop."""
    ser = estimate(GOOD.with_(kv_in_grid=False), CFG)
    par = estimate(GOOD.with_(kv_in_grid=True), CFG)
    assert par.total_s < ser.total_s


def test_gqa_pack_reduces_kv_traffic():
    cfg = BenchConfig("g", 1, 32, 4, 8192, causal=True)
    unpacked = estimate(GOOD.with_(gqa_pack=False), cfg)
    packed = estimate(GOOD.with_(gqa_pack=True), cfg)
    assert packed.t_dma_exposed <= unpacked.t_dma_exposed + 1e-12


def test_useful_flops_causal_is_half():
    uf_c = useful_flops(CFG)
    uf_nc = useful_flops(CFG_NC)
    S = CFG.seq_len
    assert uf_c / uf_nc == pytest.approx((S + 1) / (2 * S), rel=1e-6)


def test_window_reduces_useful_flops():
    w = BenchConfig("w", 1, 16, 16, 8192, causal=True, window=1024)
    assert useful_flops(w) < useful_flops(CFG)


def test_useful_flops_noncausal_window_counts_forward_side():
    """Regression: the non-causal sliding-window mask (ref.py: k > q - w)
    caps only the backward side; the forward side — previously dropped by a
    `min(S - 1 - q, 0)` term that is never positive — must be counted."""
    S, w = 512, 64
    cfg = BenchConfig("w", 1, 4, 4, S, head_dim=64, causal=False, window=w)
    pairs = sum(1 for q in range(S) for k in range(S) if k > q - w)
    assert useful_flops(cfg) == 4.0 * cfg.batch * cfg.n_heads * cfg.head_dim * pairs
    # strictly more pairs than the causal window (forward side included) and
    # strictly fewer than dense non-causal (backward side still capped)
    causal = BenchConfig("c", 1, 4, 4, S, head_dim=64, causal=True, window=w)
    dense = BenchConfig("d", 1, 4, 4, S, head_dim=64, causal=False)
    assert useful_flops(causal) < useful_flops(cfg) < useful_flops(dense)


def test_noncausal_window_profile_stays_physical():
    """The machine model visits the full forward side for a non-causal
    window, so the fixed FLOP count must still sit under the roofline."""
    cfg = BenchConfig("w", 4, 16, 16, 8192, causal=False, window=1024)
    p = estimate(EXPERT_GENOME, cfg)
    assert p.feasible
    assert p.tflops * 1e12 <= PEAK_FLOPS * 1.0001
    assert p.fraction_of_roofline <= 1.0001


def test_suites_match_paper():
    mha = mha_suite()
    assert len(mha) == 8                        # 4 seq lens x {causal, non}
    assert all(c.batch * c.seq_len == 32768 for c in mha)
    assert all(c.n_heads == 16 and c.head_dim == 128 for c in mha)
    gqa = gqa_suite()
    assert len(gqa) == 16                       # 2 kv cfgs x 4 lens x 2 masks
    assert all(c.n_heads == 32 for c in gqa)
    assert {c.n_kv_heads for c in gqa} == {4, 8}


def test_expert_beats_seed_everywhere():
    for cfg in mha_suite():
        assert expert_reference(cfg) > estimate(seed_genome(), cfg).tflops


def test_expert_and_fa_are_strong():
    """The 'vendor library' lines must sit in a plausible fraction-of-peak
    band on the big configs (FA4 on B200 reaches ~70%+ of peak)."""
    for cfg in mha_suite():
        if cfg.seq_len >= 16384:
            e = expert_reference(cfg)
            assert 0.45 * 197 < e < 197


# Deterministic sample of the property space (seeded, no runtime dependency):
# the same 40 points every run, drawn from the full cartesian product.
_PROFILE_SPACE = list(itertools.product(
    [64, 128, 256, 512],               # bq
    [128, 256, 512],                   # bk
    ["branchless", "branched"],        # rm
    ["dense", "block_skip"],           # mm
    ["deferred", "eager"],             # dm
    [False, True],                     # kg
    [False, True],                     # gp
    [4096, 8192, 16384],               # s
    [False, True],                     # causal
))
_PROFILE_CASES = random.Random(0).sample(_PROFILE_SPACE, 40)


@pytest.mark.parametrize("bq,bk,rm,mm,dm,kg,gp,s,causal", _PROFILE_CASES)
def test_property_profile_consistency(bq, bk, rm, mm, dm, kg, gp, s, causal):
    g = KernelGenome(bq, bk, rm, mm, dm, kg, gp)
    cfg = BenchConfig("p", 32768 // s, 16, 16, s, causal=causal)
    p = estimate(g, cfg)
    if not p.feasible:
        assert p.tflops == 0.0
        return
    parts = p.t_mxu + p.t_vpu_exposed + p.t_dma_exposed + p.t_overhead + p.t_bubble
    assert p.total_s > 0 and parts > 0
    # components never exceed the total by more than rounding
    assert parts <= p.total_s * 1.02 + 1e-9
    assert p.bottleneck() in ("mxu", "vpu", "dma", "overhead", "bubble")
