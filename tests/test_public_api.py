"""Public-API snapshot: the exported surface of repro.core and
repro.core.evals, pinned to checked-in lists.  A name appearing or vanishing
from __all__ is an API change and must be made deliberately — update the
snapshot in the same commit that changes the surface, and say so in the
changelog line."""
import repro.core
import repro.core.evals

CORE_EVALS_SURFACE = [
    "BackendInfo",
    "BatchScorer",
    "CORRECTNESS_TOL",
    "CascadeBackend",
    "ClientSession",
    "ElasticProcessPool",
    "EvalBackend",
    "EvalCoordinator",
    "EvalSpec",
    "FIDELITIES",
    "HLO",
    "InlineBackend",
    "MEASURED",
    "PERFMODEL",
    "ProcessBackend",
    "ScoreCache",
    "ScoreVector",
    "Scorer",
    "ServiceBackend",
    "ThreadBackend",
    "backend_info",
    "default_worker_count",
    "evaluate_genome",
    "make_backend",
    "make_process_executor",
    "register_backend",
    "registered_backends",
    "spawn_local_workers",
    "stop_local_workers",
    "unregister_backend",
]

CORE_SURFACE = [
    "AdaptiveTopology",
    "AgentPolicy",
    "AgenticVariationOperator",
    "AllToAllTopology",
    "Archipelago",
    "BatchScorer",
    "BenchConfig",
    "Commit",
    "ContinuousEvolution",
    "Directive",
    "ElasticProcessPool",
    "EngineConfig",
    "EvalBackend",
    "EvalConfig",
    "EvalCoordinator",
    "EvalSpec",
    "EvolutionReport",
    "ExplicitTopology",
    "FrontierClient",
    "InlineBackend",
    "Island",
    "IslandEvolution",
    "IslandReport",
    "IslandSpec",
    "JobEvent",
    "KernelGenome",
    "KnowledgeBase",
    "Lineage",
    "MigrationConfig",
    "MigrationStats",
    "MigrationTopology",
    "PlanExecuteSummarize",
    "PrefetchAllocator",
    "ProcessBackend",
    "RefutedMemory",
    "RingTopology",
    "ScoreCache",
    "ScoreVector",
    "Scorer",
    "ScriptedAgent",
    "SearchFrontier",
    "SearchJob",
    "ServiceBackend",
    "SingleShotMutation",
    "StarTopology",
    "Supervisor",
    "TOPOLOGIES",
    "ThreadBackend",
    "Toolbelt",
    "VariationResult",
    "backend_info",
    "decode_suite",
    "default_specs",
    "default_worker_count",
    "engine_config_from_legacy",
    "estimate",
    "evaluate_genome",
    "expert_reference",
    "fa_reference",
    "gqa_suite",
    "lineage_fingerprint",
    "make_backend",
    "make_operator",
    "make_topology",
    "mha_suite",
    "register_backend",
    "register_suite",
    "registered_backends",
    "registered_suites",
    "scenario_specs",
    "seed_genome",
    "spawn_local_workers",
    "stop_local_workers",
    "suite_by_name",
    "topology_names",
    "unregister_backend",
    "unregister_suite",
]


def _diff(actual, snapshot):
    actual, snapshot = set(actual), set(snapshot)
    return (f"added: {sorted(actual - snapshot)}; "
            f"removed: {sorted(snapshot - actual)}")


def test_core_evals_surface_matches_snapshot():
    actual = sorted(repro.core.evals.__all__)
    assert actual == sorted(CORE_EVALS_SURFACE), \
        _diff(actual, CORE_EVALS_SURFACE)


def test_core_surface_matches_snapshot():
    actual = sorted(repro.core.__all__)
    assert actual == sorted(CORE_SURFACE), _diff(actual, CORE_SURFACE)


def test_all_names_are_importable():
    for name in repro.core.__all__:
        assert getattr(repro.core, name, None) is not None, name
    for name in repro.core.evals.__all__:
        assert getattr(repro.core.evals, name, None) is not None, name
