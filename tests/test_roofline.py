"""The launch-side roofline report (repro.launch.roofline): loading and
ordering synthetic dry-run records, the per-cell diagnosis branches, the
rows_for table — whose roofline_frac must derive from the shared
perfmodel.PEAK_FLOPS constant, not a local literal — and the
roofline_terms helper the evaluation cascade's rung 1 shares with it."""
import json

import pytest

from repro.core.perfmodel import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.launch.hlo_analysis import roofline_terms
from repro.launch.roofline import (CELL_ORDER, HEADER, diagnose, load, main,
                                   rows_for)


def _rec(arch="gpt_1b", cell="train_4k", dominant="compute",
         compute=1e-3, memory=4e-4, collective=2e-4,
         coll_bytes=None, sites=None, model_flops=None, useful=0.62):
    return {
        "arch": arch, "cell": cell,
        "terms_s": {"compute": compute, "memory": memory,
                    "collective": collective},
        "dominant": dominant,
        "collectives": {"bytes": {} if coll_bytes is None else coll_bytes},
        "top_collective_sites": sites or [],
        "model_flops_per_chip": (PEAK_FLOPS * compute if model_flops is None
                                 else model_flops),
        "useful_flops_frac": useful,
    }


def _write(tmp_path, name, rec):
    (tmp_path / name).write_text(json.dumps(rec))


def test_load_filters_by_mesh_and_orders_by_arch_then_cell(tmp_path):
    _write(tmp_path, "b__decode_32k__pod1.json", _rec("b", "decode_32k"))
    _write(tmp_path, "a__prefill_32k__pod1.json", _rec("a", "prefill_32k"))
    _write(tmp_path, "a__train_4k__pod1.json", _rec("a", "train_4k"))
    _write(tmp_path, "a__weird__pod1.json", _rec("a", "not_a_cell"))
    _write(tmp_path, "a__train_4k__pod2.json", _rec("zzz", "train_4k"))
    recs = load("pod1", str(tmp_path))
    assert [(r["arch"], r["cell"]) for r in recs] == [
        ("a", "train_4k"), ("a", "prefill_32k"), ("a", "not_a_cell"),
        ("b", "decode_32k")]                  # unknown cells sort last per arch
    assert all(c in CELL_ORDER for c in ("train_4k", "prefill_32k",
                                         "decode_32k", "long_500k"))
    assert load("pod3", str(tmp_path)) == []


def test_diagnose_covers_each_dominant_branch():
    coll = {"all-reduce": 3e9, "all-gather": 1e9}
    sites = [["fused_allreduce_in_backward_pass_of_layer_0", 3e9]]
    d = diagnose(_rec(dominant="collective", coll_bytes=coll, sites=sites))
    assert "all-reduce" in d and "fused_allreduce" in d
    # no recorded sites: placeholder, not a crash
    assert "?" in diagnose(_rec(dominant="collective", coll_bytes=coll))
    assert "HBM-bound" in diagnose(_rec(cell="decode_32k", dominant="memory"))
    assert "cache" in diagnose(_rec(cell="long_500k", dominant="memory"))
    assert "activation" in diagnose(_rec(cell="train_4k", dominant="memory"))
    assert "MXU-bound" in diagnose(_rec(dominant="compute"))


def test_rows_for_roofline_frac_comes_from_shared_peak():
    """Satellite fix: the ideal step time is model_flops / PEAK_FLOPS with
    the perfmodel constant — a cell whose bound term exactly equals that
    ideal reads 1.00, and scaling the bound halves the fraction."""
    at_peak = _rec(compute=2e-3, memory=1e-3, collective=1e-3,
                   model_flops=PEAK_FLOPS * 2e-3)
    half = _rec(compute=4e-3, memory=1e-3, collective=1e-3,
                model_flops=PEAK_FLOPS * 2e-3)
    rows = rows_for([at_peak, half])
    assert len(rows[0]) == len(HEADER)
    frac_col = HEADER.index("roofline_frac")
    assert rows[0][frac_col] == "1.00"
    assert rows[1][frac_col] == "0.50"
    assert rows[0][HEADER.index("dominant")] == "compute"


def test_rows_for_zero_bound_is_safe():
    rec = _rec(compute=0.0, memory=0.0, collective=0.0, model_flops=0.0)
    assert rows_for([rec])[0][HEADER.index("roofline_frac")] == "0.00"


def test_main_renders_synthetic_records(tmp_path, capsys):
    _write(tmp_path, "a__train_4k__pod1.json", _rec())
    main(["--dir", str(tmp_path)])
    out = capsys.readouterr().out
    assert "roofline_frac" in out and "dominant-term counts" in out
    main(["--dir", str(tmp_path), "--markdown"])
    assert capsys.readouterr().out.startswith("| arch |")
    with pytest.raises(FileNotFoundError):
        main(["--dir", str(tmp_path), "--mesh", "pod2"])


def test_roofline_terms_three_term_model():
    """The helper rung 1 of the evaluation cascade scores with: seconds per
    term from the same machine constants the launch report uses."""
    summary = {"flops": PEAK_FLOPS * 1e-3, "bytes_accessed": HBM_BW * 2e-3,
               "collective_total_bytes": ICI_BW * 5e-4}
    t = roofline_terms(summary)
    assert t["compute"] == pytest.approx(1e-3)
    assert t["memory"] == pytest.approx(2e-3)
    assert t["collective"] == pytest.approx(5e-4)
    assert roofline_terms({}) == {"compute": 0.0, "memory": 0.0,
                                  "collective": 0.0}
