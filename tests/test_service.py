"""The cross-host evaluation service: wire protocol, worker registry +
heartbeats, ServiceBackend bit-identity with inline (the acceptance gate),
dead-worker requeue onto survivors, and engine lineages surviving a worker
kill unchanged."""
import socket
import threading
import time

import pytest

from repro.core import (Archipelago, IslandEvolution, Scorer, make_backend,
                        seed_genome)
from repro.core.evals import (EvalCoordinator, EvalSpec, ServiceBackend,
                              protocol)
from repro.core.evals.service_worker import EvalServiceWorker
from repro.core.perfmodel import BenchConfig

FAST_SUITE = [BenchConfig("c4k", 8, 16, 16, 4096, causal=True),
              BenchConfig("n4k", 8, 16, 16, 4096, causal=False)]


def _inproc_worker(address, slots=1, name="inproc"):
    """Worker on a thread inside the test process: registration, dispatch,
    and identity paths without process spin-up cost.  (Fault tests use real
    killed subprocesses — a thread cannot be SIGKILLed.)"""
    w = EvalServiceWorker(*address, slots=slots, name=name)
    t = threading.Thread(target=w.run, daemon=True)
    t.start()
    return w, t


# -- the wire protocol ---------------------------------------------------------


def test_protocol_roundtrip_and_eof():
    a, b = socket.socketpair()
    try:
        g = seed_genome()
        spec = EvalSpec.resolve(FAST_SUITE, check_correctness=False)
        protocol.send_msg(a, {"type": protocol.TASK, "id": 7, "spec": spec,
                              "genome": g})
        msg = protocol.recv_msg(b)
        assert msg["id"] == 7 and msg["spec"] == spec
        assert msg["genome"].key() == g.key()
        a.close()
        with pytest.raises(ConnectionError):
            protocol.recv_msg(b)
    finally:
        b.close()


def test_parse_address():
    assert protocol.parse_address("10.0.0.3:5123") == ("10.0.0.3", 5123)
    with pytest.raises(ValueError, match="HOST:PORT"):
        protocol.parse_address("5123")


# -- registry + dispatch --------------------------------------------------------


def test_worker_registers_and_join_event_observable():
    coord = EvalCoordinator()
    try:
        assert not coord.wait_for_workers(1, timeout=0.05)
        w, t = _inproc_worker(coord.address, slots=2, name="alpha")
        assert coord.wait_for_workers(1, timeout=10)
        st = coord.stats()
        assert st["workers"] == 1 and st["total_slots"] == 2
        assert st["events"][0] == {"event": "join", "worker": "alpha",
                                   "slots": 2, "workers": 1}
        w.stop()
        t.join(5)
    finally:
        coord.close()


def test_service_backend_bit_identical_to_inline():
    """The acceptance gate: a fixed genome batch scored over the socket
    transport must be bit-identical to the inline path — correctness
    verdicts, per-config TFLOPS, and profile breakdowns."""
    suite = [BenchConfig("c2k", 1, 4, 4, 2048, causal=True)]
    genomes = [seed_genome(),
               seed_genome().with_(block_q=512, kv_in_grid=True),
               seed_genome().with_(mask_mode="block_skip",
                                   rescale_mode="branchless"),
               seed_genome().with_(acc_dtype="bf16")]   # fails correctness
    svc = ServiceBackend(suite=suite, workers=0)
    w, t = _inproc_worker(svc.address, slots=2)
    try:
        assert svc.coordinator.wait_for_workers(1, timeout=10)
        got = svc.map(genomes)
    finally:
        svc.close()
        w.stop()
        t.join(5)
    want = make_backend("inline", suite=suite).map(genomes)
    for a, b in zip(got, want):
        assert a.correct == b.correct
        assert a.values == b.values              # bit-identical, no approx
        assert a.config_names == b.config_names
        assert a.failure == b.failure
        assert {n: p.breakdown() for n, p in a.profiles.items()} == \
            {n: p.breakdown() for n, p in b.profiles.items()}
    assert not want[-1].correct                  # the bf16 trap really fired


def test_service_backend_dedup_and_parent_cache():
    svc = ServiceBackend(suite=FAST_SUITE, check_correctness=False, workers=0)
    w, t = _inproc_worker(svc.address, slots=2)
    try:
        assert svc.coordinator.wait_for_workers(1, timeout=10)
        g1, g2 = seed_genome(), seed_genome().with_(block_q=256)
        svs = svc.map([g1, g2, g1, g2, g1])      # duplicates share one task
        assert svc.n_evaluations == 2
        assert [sv.values for sv in svs[:2]] == [svs[2].values, svs[3].values]
        before = svc.n_evaluations
        again = svc.map([g1, g2])                # parent cache: no new tasks
        assert svc.n_evaluations == before
        assert svc.cache_hits >= 2
        assert [a.values for a in again] == [svs[0].values, svs[1].values]
        assert svc.in_flight == ()
    finally:
        svc.close()
        w.stop()
        t.join(5)


def test_remote_evaluation_failure_propagates_and_is_not_cached():
    """A deterministic evaluation failure must propagate (never requeue —
    retrying a poisoned genome elsewhere would loop forever) and must not
    poison the cache for a later valid spec."""
    coord = EvalCoordinator()
    w, t = _inproc_worker(coord.address)
    try:
        assert coord.wait_for_workers(1, timeout=10)
        bad_spec = EvalSpec(suite=("not-a-config",), check_correctness=False)
        bad = ServiceBackend(spec=bad_spec, coordinator=coord)
        fut = bad.submit(seed_genome())
        with pytest.raises(RuntimeError, match="remote evaluation failed"):
            fut.result(20)
        assert bad.in_flight == ()               # evicted, retry possible
        good = ServiceBackend(suite=FAST_SUITE, check_correctness=False,
                              coordinator=coord)
        assert good(seed_genome()).values        # fleet still healthy
        bad.close(); good.close()
    finally:
        coord.close()
        w.stop()
        t.join(5)


def test_shared_coordinator_serves_multiple_suites():
    """One worker fleet, many suites: each task carries its spec, so the
    island engine's per-suite backends share a single coordinator."""
    coord = EvalCoordinator()
    w, t = _inproc_worker(coord.address, slots=2)
    try:
        assert coord.wait_for_workers(1, timeout=10)
        a = ServiceBackend(suite=FAST_SUITE, check_correctness=False,
                           coordinator=coord)
        b = ServiceBackend(suite="decode", check_correctness=False,
                           coordinator=coord)
        g = seed_genome()
        sva, svb = a(g), b(g)
        assert sva.config_names != svb.config_names
        assert sva.values == Scorer(suite=FAST_SUITE,
                                    check_correctness=False)(g).values
        a.close()
        b.close()                                # coordinator stays shared
        assert coord.n_workers == 1
        with pytest.raises(ValueError, match="owned-coordinator only"):
            ServiceBackend(suite=FAST_SUITE, coordinator=coord, workers=2)
    finally:
        coord.close()
        w.stop()
        t.join(5)


def test_coordinator_close_cancels_pending_and_rejects_submit():
    coord = EvalCoordinator()
    spec = EvalSpec.resolve(FAST_SUITE, check_correctness=False)
    fut = coord.submit(spec, seed_genome())      # no workers: stays queued
    coord.close()
    assert fut.cancelled()
    coord.close()                                # idempotent
    with pytest.raises(RuntimeError, match="closed EvalCoordinator"):
        coord.submit(spec, seed_genome())


def test_garbage_frames_do_not_kill_the_coordinator():
    """A listener bound for remote workers will meet stray clients: garbage
    bytes at the handshake must be rejected quietly, and a corrupt frame
    from a REGISTERED worker must take the synchronous death path (eviction
    with a leave event), never leave a zombie registration behind."""
    import struct
    coord = EvalCoordinator()
    try:
        stray = socket.create_connection(coord.address)
        stray.sendall(b"GET / HTTP/1.1\r\n\r\n")   # not a frame at all
        stray.close()
        corrupt = socket.create_connection(coord.address)
        protocol.send_msg(corrupt, {"type": protocol.HELLO, "name": "bad",
                                    "slots": 1})
        assert coord.wait_for_workers(1, timeout=10)
        corrupt.sendall(struct.pack(">I", 4) + b"junk")  # unpicklable frame
        deadline = time.monotonic() + 10
        while coord.n_workers and time.monotonic() < deadline:
            time.sleep(0.05)
        assert coord.n_workers == 0
        assert any(e["event"] == "leave" and "protocol error" in e["why"]
                   for e in coord.stats()["events"])
        corrupt.close()
        w, t = _inproc_worker(coord.address)      # fleet still serviceable
        assert coord.wait_for_workers(1, timeout=10)
        w.stop()
        t.join(5)
    finally:
        coord.close()


# -- fault tolerance ------------------------------------------------------------


def test_missed_heartbeats_evict_worker_and_requeue_onto_survivor():
    """The asynchronous death path: a registered worker that goes silent
    (hang/partition — the socket stays open) is evicted after dead_after_s
    and its in-flight task completes on a later-joining live worker."""
    coord = EvalCoordinator(heartbeat_s=0.1, dead_after_s=0.4)
    zombie = socket.create_connection(coord.address)
    try:
        protocol.send_msg(zombie, {"type": protocol.HELLO, "name": "zombie",
                                   "slots": 1})
        assert coord.wait_for_workers(1, timeout=10)
        spec = EvalSpec.resolve(FAST_SUITE, check_correctness=False)
        fut = coord.submit(spec, seed_genome())  # dispatched to the zombie
        deadline = time.monotonic() + 10
        while coord.n_workers and time.monotonic() < deadline:
            time.sleep(0.05)
        assert coord.n_workers == 0              # evicted, not still trusted
        events = coord.stats()["events"]
        assert any(e["event"] == "leave" and "heartbeat" in e["why"]
                   for e in events)
        assert any(e["event"] == "requeue" for e in events)
        w, t = _inproc_worker(coord.address, name="live")
        assert fut.result(30).values             # survivor finished the task
        w.stop()
        t.join(5)
    finally:
        zombie.close()
        coord.close()


def test_worker_kill_mid_batch_requeues_onto_survivor():
    """The synchronous death path, with real processes: SIGKILL one of two
    workers while both are mid-evaluation; every future must still complete,
    bit-identical to inline, and the registry must record leave+requeue."""
    spec = EvalSpec.resolve(FAST_SUITE, check_correctness=False,
                            service_latency_s=0.5)
    svc = ServiceBackend(spec=spec, workers=2)
    try:
        genomes = [seed_genome().with_(block_q=bq, block_k=bk)
                   for bq in (64, 128, 256, 512) for bk in (64, 128)]
        futs = [svc.submit(g) for g in genomes]
        time.sleep(0.6)                          # both workers mid-evaluation
        svc._procs[0].kill()
        got = [f.result(60) for f in futs]
        inline = Scorer(suite=FAST_SUITE, check_correctness=False)
        assert [sv.values for sv in got] == [inline(g).values for g in genomes]
        st = svc.coordinator.stats()
        assert st["tasks_requeued"] >= 1
        assert any(e["event"] == "leave" for e in st["events"])
        assert any(e["event"] == "requeue" for e in st["events"])
        assert st["workers"] == 1                # the survivor
    finally:
        svc.close()


def test_engine_lineage_unchanged_by_worker_kill():
    """The end-to-end fault gate: an island run whose service loses a worker
    mid-flight commits the exact lineage of an uninterrupted (inline) run —
    requeue + determinism make worker death invisible to the search."""
    def fingerprint(eng):
        return {i.name: [(c.genome.key(), round(c.geomean, 9), c.note)
                         for c in i.lineage.commits] for i in eng.islands}

    kw = dict(n_islands=2, suite=FAST_SUITE, migration_interval=2, seed=11,
              check_correctness=False)
    base = IslandEvolution(backend="inline", **kw)
    try:
        base.run(max_steps=4)
        want = fingerprint(base)
    finally:
        base.close()

    eng = IslandEvolution(backend="service", service_workers=2, **kw)
    try:
        eng.run(max_steps=2)                     # both workers serving
        eng._service_procs[0].kill()             # lose one mid-run
        deadline = time.monotonic() + 20
        while eng.service_coordinator.n_workers > 1 \
                and time.monotonic() < deadline:
            time.sleep(0.05)
        assert eng.service_coordinator.n_workers == 1
        eng.run(max_steps=2)                     # survivor carries the rest
        assert fingerprint(eng) == want
        assert eng.service_coordinator.stats()["left"] == 1
    finally:
        eng.close()


# -- engine integration ---------------------------------------------------------


def test_engine_rejects_service_workers_without_service_backend():
    with pytest.raises(ValueError, match="service_workers requires"):
        Archipelago(n_islands=2, suite=FAST_SUITE, backend="thread",
                    service_workers=2)
