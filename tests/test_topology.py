"""Migration topologies: edge generation, acceptance-EMA stats, adaptive
prune/trial scheduling, and engine integration incl. exact kill/resume."""
import os

import pytest

from repro.core import (AdaptiveTopology, AllToAllTopology, BenchConfig,
                        ExplicitTopology, IslandEvolution, MigrationStats,
                        RingTopology, StarTopology, make_topology,
                        topology_names)
from repro.core.topology import ring_edges

FAST_SUITE = [BenchConfig("c4k", 8, 16, 16, 4096, causal=True),
              BenchConfig("n4k", 8, 16, 16, 4096, causal=False)]


def _fingerprint(eng):
    return {i.name: [(c.genome.key(), round(c.geomean, 9), c.note)
                     for c in i.lineage.commits] for i in eng.islands}


def _engine(**kw):
    defaults = dict(n_islands=3, suite=FAST_SUITE, migration_interval=2,
                    seed=11)
    defaults.update(kw)
    return IslandEvolution(**defaults)


# -- stateless topologies ---------------------------------------------------------


def test_ring_edges_order_and_single_island():
    t = RingTopology()
    assert t.edges(4, MigrationStats()) == [(0, 1), (1, 2), (2, 3), (3, 0)]
    assert t.edges(1, MigrationStats()) == []      # no self-migration
    assert ring_edges(2) == [(0, 1), (1, 0)]


def test_star_hub_is_best_coverage_island():
    t = StarTopology()
    stats = MigrationStats()
    stats.island_best = [10.0, 99.0, 50.0]
    assert StarTopology.hub(3, stats) == 1
    edges = t.edges(3, stats)
    assert edges == [(0, 1), (2, 1), (1, 0), (1, 2)]   # spokes in, hub out
    # no record yet -> island 0 is the hub; single island -> no edges
    assert StarTopology.hub(3, MigrationStats()) == 0
    assert t.edges(1, stats) == []


def test_all_to_all_covers_every_ordered_pair():
    edges = AllToAllTopology().edges(3, MigrationStats())
    assert len(edges) == 6 and len(set(edges)) == 6
    assert all(s != d for s, d in edges)


def test_explicit_topology_filters_and_rewires():
    t = ExplicitTopology([(0, 1), (1, 1), (5, 0), (1, 2)])
    assert t.edges(3, MigrationStats()) == [(0, 1), (1, 2)]  # self/oob dropped
    t.remove_edge(0, 1)
    t.add_edge(2, 0)
    assert t.edges(3, MigrationStats()) == [(1, 2), (2, 0)]
    t2 = ExplicitTopology()
    t2.load_state(t.state())
    assert t2.edges(3, MigrationStats()) == t.edges(3, MigrationStats())


def test_make_topology_registry():
    assert set(topology_names()) == {"ring", "star", "all-to-all", "adaptive"}
    assert isinstance(make_topology("ring"), RingTopology)
    assert isinstance(make_topology("all_to_all"), AllToAllTopology)
    inst = ExplicitTopology([(0, 1)])
    assert make_topology(inst) is inst             # instances pass through
    with pytest.raises(ValueError):
        make_topology("torus")


# -- MigrationStats ---------------------------------------------------------------


def test_migration_stats_ema_and_roundtrip():
    s = MigrationStats(alpha=0.5)
    s.record(0, 1, True)
    assert s.ema((0, 1)) == 1.0                    # first sample sets the EMA
    s.record(0, 1, False)
    assert s.ema((0, 1)) == pytest.approx(0.5)
    s.record(0, 1, False)
    assert s.ema((0, 1)) == pytest.approx(0.25)
    assert s.attempts((0, 1)) == 3 and s.accepts((0, 1)) == 1
    assert s.attempts((1, 0)) == 0 and s.ema((1, 0), default=0.7) == 0.7

    s2 = MigrationStats.from_payload(s.to_payload())
    assert s2.to_payload() == s.to_payload()
    assert s2.ema((0, 1)) == s.ema((0, 1))


def test_donor_quality_aggregates_outgoing_edges():
    s = MigrationStats(alpha=1.0)
    s.record(0, 1, True)
    s.record(0, 2, False)
    assert s.donor_quality(0) == pytest.approx(0.5)
    assert s.donor_quality(3) == 0.5               # unobserved -> the floor


# -- AdaptiveTopology -------------------------------------------------------------


def test_adaptive_starts_as_ring_and_is_deterministic():
    stats = MigrationStats()
    a, b = AdaptiveTopology(seed=7), AdaptiveTopology(seed=7)
    seq_a = [a.edges(3, stats) for _ in range(6)]
    seq_b = [b.edges(3, stats) for _ in range(6)]
    assert seq_a[0] == ring_edges(3)
    assert seq_a == seq_b                          # same seed, same schedule
    # trials happen on the schedule: edges can only be added (stats are empty,
    # so nothing is ever pruned) and at least one trial has fired by epoch 6
    assert len(seq_a[-1]) > len(seq_a[0])


def test_adaptive_prunes_dead_edge_but_never_isolates():
    stats = MigrationStats(alpha=1.0)
    t = AdaptiveTopology(seed=0, prune_after=2, prune_below=0.5,
                         trial_interval=1000)      # no trials in this test
    t.load_state({"epoch": 1, "n": 3,
                  "active": [[0, 1], [1, 2], [2, 0], [0, 2]]})
    for _ in range(3):                             # (0,2) keeps getting refused
        stats.record(0, 2, False)
    edges = t.edges(3, stats)
    assert (0, 2) not in edges                     # dead extra edge pruned
    assert set(edges) == set(ring_edges(3))
    # the same dead stats on a pure ring edge must NOT prune it: removal
    # would leave island 0 with no outgoing (or 1 with no incoming) edge
    for _ in range(3):
        stats.record(0, 1, False)
    assert (0, 1) in t.edges(3, stats)


def test_adaptive_state_roundtrip_resumes_schedule():
    stats = MigrationStats()
    a = AdaptiveTopology(seed=3)
    for _ in range(3):
        a.edges(4, stats)
    b = AdaptiveTopology(seed=3)
    b.load_state(a.state())
    assert b.state() == a.state()
    for _ in range(4):                             # identical future decisions
        assert a.edges(4, stats) == b.edges(4, stats)


# -- engine integration -----------------------------------------------------------


@pytest.mark.parametrize("topo", ["ring", "star", "all-to-all", "adaptive"])
def test_single_island_archipelago_never_self_migrates(topo):
    eng = _engine(n_islands=1, topology=topo)
    try:
        rep = eng.run(max_steps=4)
        assert rep.commits > 0
        assert rep.migrations_accepted == 0
        assert eng.migration_stats.edges == {}     # no attempt was recorded
    finally:
        eng.close()


def test_engine_ring_default_matches_explicit_ring_topology():
    eng1 = _engine()
    eng2 = _engine(topology=RingTopology())
    try:
        eng1.run(max_steps=4)
        eng2.run(max_steps=4)
        assert _fingerprint(eng1) == _fingerprint(eng2)
    finally:
        eng1.close()
        eng2.close()


def test_engine_records_acceptance_stats_per_edge():
    eng = _engine(topology="all-to-all")
    try:
        rep = eng.run(max_steps=4)
        attempts = sum(st.attempts for st in eng.migration_stats.edges.values())
        accepts = sum(st.accepts for st in eng.migration_stats.edges.values())
        assert attempts > 0
        assert accepts == rep.migrations_accepted == eng.migrations_accepted
    finally:
        eng.close()


def test_removed_edge_mid_run_stops_migrating(tmp_path):
    topo = ExplicitTopology([(0, 1), (1, 0)])
    eng = _engine(n_islands=2, topology=topo)
    try:
        eng.run(max_steps=2)                       # one epoch with both edges
        assert eng.migration_stats.attempts((0, 1)) > 0
        frozen = eng.migration_stats.attempts((0, 1))
        topo.remove_edge(0, 1)
        eng.run(max_steps=4)                       # two more epochs
        assert eng.migration_stats.attempts((0, 1)) == frozen  # edge is gone
        assert eng.migration_stats.attempts((1, 0)) > frozen   # other kept going
    finally:
        eng.close()


def test_topology_state_persisted_and_restored(tmp_path):
    p = str(tmp_path / "arch.json")
    eng = _engine(topology="adaptive", persist_path=p)
    try:
        eng.run(max_steps=4)
        topo_state = eng.topology.state()
        stats = eng.migration_stats.to_payload()
        assert topo_state["epoch"] > 0
    finally:
        eng.close()

    fresh = _engine(topology="adaptive")
    try:
        fresh.load_state(p)
        assert fresh.topology.state() == topo_state
        assert fresh.migration_stats.to_payload() == stats
    finally:
        fresh.close()

    # a different topology family must NOT adopt foreign state
    other = _engine(topology="ring")
    try:
        other.load_state(p)
        assert other.topology.state() == {}
        # … but the stats ledger is engine-owned and still restores
        assert other.migration_stats.to_payload() == stats
    finally:
        other.close()


def test_adaptive_killed_run_resumes_exact_migration_decisions(tmp_path):
    """The PR's hard gate: kill/resume under AdaptiveTopology must make the
    same migration decisions as an uninterrupted run, step for step."""
    kw = dict(n_islands=3, suite=FAST_SUITE, migration_interval=2, seed=11,
              topology="adaptive")

    def full(eng):
        return (_fingerprint(eng), eng.migration_stats.to_payload(),
                eng.topology.state(), eng.migrations_accepted)

    a = IslandEvolution(persist_path=str(tmp_path / "a.json"), **kw)
    try:
        a.run(max_steps=8)
        uninterrupted = full(a)
    finally:
        a.close()

    pb = str(tmp_path / "b.json")
    b1 = IslandEvolution(persist_path=pb, **kw)
    try:
        b1.run(max_steps=4)
    finally:
        b1.close()                                 # "kill" mid-run
    b2 = IslandEvolution.resume(pb, **kw)
    try:
        b2.run(max_steps=4)
        assert full(b2) == uninterrupted
    finally:
        b2.close()


def test_from_registry_threads_topology():
    eng = IslandEvolution.from_registry(suites=("mha", "decode"),
                                        topology="star", seed=2)
    try:
        assert eng.topology.name == "star"
        assert [i.name for i in eng.islands] == ["mha", "decode"]
    finally:
        eng.close()
