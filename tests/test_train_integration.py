"""End-to-end training integration: loss descends, microbatching is exact,
gradient compression trains, serving produces consistent generations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.data.pipeline import TokenPipeline
from repro.launch.serve import BatchedServer, Request
from repro.launch.train import (default_microbatches, init_train_state,
                                make_train_step)
from repro.models import init_params
from repro.optim import AdamWConfig


def _jax_batch(b):
    return {k: jnp.asarray(v) for k, v in b.items()}


def test_loss_descends_30_steps():
    cfg = get_arch("qwen2-7b").reduced()
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100)
    params, opt_state, residual = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt, compute_dtype=jnp.float32))
    pipe = TokenPipeline(cfg, 32, 8, seed=0)
    losses = []
    for _ in range(30):
        params, opt_state, residual, m = step(params, opt_state, residual,
                                              _jax_batch(pipe.next_batch()))
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses
    # must beat the uniform-prediction baseline
    assert np.mean(losses[-5:]) < np.log(cfg.vocab_size)


def test_microbatching_matches_full_batch():
    cfg = get_arch("qwen2-7b").reduced()
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10**6,
                      max_grad_norm=100.0)
    params, opt_state, residual = init_train_state(cfg, jax.random.PRNGKey(0))
    pipe = TokenPipeline(cfg, 16, 8, seed=1)
    batch = _jax_batch(pipe.next_batch())

    s1 = make_train_step(cfg, opt, n_microbatches=1, compute_dtype=jnp.float32)
    s4 = make_train_step(cfg, opt, n_microbatches=4, compute_dtype=jnp.float32)
    p1, _, _, m1 = s1(params, opt_state, residual, batch)
    p4, _, _, m4 = s4(params, opt_state, residual, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=2e-4)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-5, rtol=5e-4)


@pytest.mark.parametrize("compression", ["bf16", "int8_ef"])
def test_training_with_grad_compression(compression):
    cfg = get_arch("mamba2-780m").reduced()
    opt = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=50)
    params, opt_state, residual = init_train_state(cfg, jax.random.PRNGKey(0),
                                                   compression=compression)
    step = jax.jit(make_train_step(cfg, opt, compression=compression,
                                   compute_dtype=jnp.float32))
    pipe = TokenPipeline(cfg, 32, 4, seed=0)
    losses = []
    for _ in range(15):
        params, opt_state, residual, m = step(params, opt_state, residual,
                                              _jax_batch(pipe.next_batch()))
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


def test_default_microbatches_bounds_logit_temp():
    cfg = get_arch("gemma2-27b")           # 256k vocab
    nm = default_microbatches(cfg, 256)
    assert (256 // nm) * 4096 * 0 + (256 // nm) * cfg.vocab_size <= 1 << 31
    assert 256 % nm == 0


def test_moe_arch_trains():
    cfg = get_arch("moonshot-v1-16b-a3b").reduced()
    opt = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=50)
    params, opt_state, residual = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt, compute_dtype=jnp.float32))
    pipe = TokenPipeline(cfg, 32, 4, seed=0)
    losses = []
    for _ in range(10):
        params, opt_state, residual, m = step(params, opt_state, residual,
                                              _jax_batch(pipe.next_batch()))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


# -- serving ----------------------------------------------------------------


def test_batched_server_greedy_selfconsistent(tiny_archs):
    cfg = tiny_archs["qwen2-7b"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (5, 7, 3, 6)]
    server = BatchedServer(cfg, params, batch_size=2, max_len=32)
    reqs = [Request(i, p, max_new_tokens=6) for i, p in enumerate(prompts)]
    done = server.run(reqs)
    assert all(r.done and len(r.output) == 6 for r in done)
    # same prompt in a different group position -> same greedy continuation
    reqs2 = [Request(0, prompts[0], max_new_tokens=6),
             Request(1, prompts[0], max_new_tokens=6)]
    done2 = BatchedServer(cfg, params, batch_size=2, max_len=32).run(reqs2)
    assert done2[0].output == done2[1].output


def test_server_matches_manual_prefill_decode(tiny_archs):
    from repro.models import decode_step, prefill
    cfg = tiny_archs["mamba2-780m"]
    params = init_params(cfg, jax.random.PRNGKey(1))
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)
    server = BatchedServer(cfg, params, batch_size=1, max_len=32)
    (req,) = server.run([Request(0, prompt, max_new_tokens=4)])

    logits, cache = prefill(params, cfg, jnp.asarray(prompt)[None], 32,
                            compute_dtype=jnp.float32, cache_dtype=jnp.float32)
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(4):
        toks.append(int(tok[0]))
        logits, cache = decode_step(params, cfg, cache, tok,
                                    compute_dtype=jnp.float32)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert req.output == toks
